//! `c2dfb lint` — a std-only static-analysis pass that machine-checks
//! the repo's determinism and hostile-input contracts at the source
//! level (docs/LINT.md).
//!
//! The value proposition this crate sells — bit-identical parallel
//! sweeps, byte-stable goldens, a wall-clock-free trace, a decode path
//! that never panics on attacker bytes — is otherwise enforced only at
//! runtime, after a careless `Instant::now()` or `HashMap` iteration has
//! already shipped.  This pass refuses those constructs up front:
//!
//! * [`lexer`] — a small string/char/comment/raw-string-aware Rust
//!   lexer, so rules never fire inside literals or docs;
//! * [`rules`] — the R1–R6 catalog, each grounded in a documented
//!   contract;
//! * [`config`] — `rust/lint.toml`, the checked-in per-rule scopes and
//!   reason-carrying allowlist.
//!
//! The pass is self-testing (`tests/lint.rs`: one bad fixture per rule
//! must trigger exactly that rule; the full `src/` tree must pass
//! clean) and runs in CI as a hard gate alongside `cargo clippy`
//! (rust/clippy.toml carries the toolchain-native twin of R1/R2).

pub mod config;
pub mod lexer;
pub mod rules;

pub use config::{path_matches, AllowEntry, LintConfig};
pub use rules::{Finding, RuleInfo, RULES};

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Result of linting a file set.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    /// Files scanned (deterministic sorted order).
    pub files: Vec<String>,
    /// Allowlist entries that suppressed at least one finding.
    pub used_allows: Vec<AllowEntry>,
    /// Allowlist entries that matched nothing — stale, candidates for
    /// deletion (reported, not fatal).
    pub unused_allows: Vec<AllowEntry>,
}

/// Lint one in-memory source file: scope rules by `path`, run them, then
/// apply the allowlist.  Returns surviving findings plus the indices of
/// allow entries that suppressed something.
fn lint_source_impl(
    path: &str,
    src: &str,
    cfg: &LintConfig,
) -> (Vec<Finding>, Vec<usize>) {
    let toks = lexer::lex(src);
    let raw = rules::run_rules(path, &toks, |rule| cfg.rule_applies(rule, path));
    let mut used = Vec::new();
    let mut kept = Vec::new();
    for finding in raw {
        match cfg.allow_for(finding.rule, path) {
            Some(idx) => {
                if !used.contains(&idx) {
                    used.push(idx);
                }
            }
            None => kept.push(finding),
        }
    }
    (kept, used)
}

/// Public single-file entry point (the allowlist is applied).
pub fn lint_source(path: &str, src: &str, cfg: &LintConfig) -> Vec<Finding> {
    lint_source_impl(path, src, cfg).0
}

/// Recursively collect `.rs` files under `root` in sorted order.
fn collect_rs(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(root)
        .map_err(|e| format!("reading {}: {e}", root.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint a set of files/directories against `cfg`.
pub fn lint_tree(paths: &[String], cfg: &LintConfig) -> Result<LintReport, String> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(Path::new(p), &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = LintReport::default();
    let mut used_all: Vec<usize> = Vec::new();
    for file in &files {
        let rel = file.to_string_lossy().replace('\\', "/");
        let src = std::fs::read_to_string(file)
            .map_err(|e| format!("reading {}: {e}", file.display()))?;
        let (findings, used) = lint_source_impl(&rel, &src, cfg);
        report.findings.extend(findings);
        for u in used {
            if !used_all.contains(&u) {
                used_all.push(u);
            }
        }
        report.files.push(rel);
    }
    for (i, a) in cfg.allows.iter().enumerate() {
        if used_all.contains(&i) {
            report.used_allows.push(a.clone());
        } else {
            report.unused_allows.push(a.clone());
        }
    }
    Ok(report)
}

impl LintReport {
    /// Stable machine-readable form (schema pinned by `tests/lint.rs`):
    /// `{"version":1,"findings":[{rule,path,line,message}],
    ///   "files_scanned":N,"allow_used":N,"allow_unused":[…]}`.
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("rule", Json::str(f.rule)),
                    ("path", Json::str(&f.path)),
                    ("line", Json::num(f.line as f64)),
                    ("message", Json::str(&f.message)),
                ])
            })
            .collect();
        let unused: Vec<Json> = self
            .unused_allows
            .iter()
            .map(|a| Json::obj(vec![("rule", Json::str(&a.rule)), ("path", Json::str(&a.path))]))
            .collect();
        Json::obj(vec![
            ("version", Json::num(1.0)),
            ("findings", Json::Arr(findings)),
            ("files_scanned", Json::num(self.files.len() as f64)),
            ("allow_used", Json::num(self.used_allows.len() as f64)),
            ("allow_unused", Json::Arr(unused)),
        ])
    }

    /// Human-readable form, one `path:line: rule name: message` per
    /// finding (clickable in most terminals/editors).
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let name = RULES
                .iter()
                .find(|r| r.id == f.rule)
                .map(|r| r.name)
                .unwrap_or("?");
            out.push_str(&format!(
                "{}:{}: {} {}: {}\n",
                f.path, f.line, f.rule, name, f.message
            ));
        }
        for a in &self.unused_allows {
            out.push_str(&format!(
                "note: stale allowlist entry {} {} (matched nothing; delete it)\n",
                a.rule, a.path
            ));
        }
        out.push_str(&format!(
            "{} finding(s) in {} file(s); {} allowlist entr{} in use\n",
            self.findings.len(),
            self.files.len(),
            self.used_allows.len(),
            if self.used_allows.len() == 1 { "y" } else { "ies" },
        ));
        out
    }
}

/// `--fix-safety-stubs`: insert a `// SAFETY: FIXME` stub above every R4
/// finding so the violation is visible in the diff (the stub still needs
/// a human argument; the lint keeps failing until the FIXME is replaced
/// — the stub only localizes the work).  Returns stubs written.
pub fn fix_safety_stubs(report: &LintReport) -> Result<usize, String> {
    let mut by_file: Vec<(&str, Vec<u32>)> = Vec::new();
    for f in report.findings.iter().filter(|f| f.rule == "R4") {
        match by_file.iter_mut().find(|(p, _)| *p == f.path) {
            Some((_, lines)) => lines.push(f.line),
            None => by_file.push((&f.path, vec![f.line])),
        }
    }
    let mut written = 0usize;
    for (path, mut lines) in by_file {
        let text = std::fs::read_to_string(path).map_err(|e| format!("reading {path}: {e}"))?;
        let mut out: Vec<String> = text.lines().map(|l| l.to_string()).collect();
        lines.sort_unstable();
        lines.dedup();
        // Insert bottom-up so earlier line numbers stay valid.
        for &line in lines.iter().rev() {
            let idx = (line as usize).saturating_sub(1).min(out.len());
            let indent: String = out
                .get(idx)
                .map(|l| l.chars().take_while(|c| c.is_whitespace()).collect())
                .unwrap_or_default();
            out.insert(
                idx,
                format!("{indent}// SAFETY: FIXME(c2dfb lint): argue why this unsafe is sound."),
            );
            written += 1;
        }
        let mut joined = out.join("\n");
        if text.ends_with('\n') {
            joined.push('\n');
        }
        std::fs::write(path, joined).map_err(|e| format!("writing {path}: {e}"))?;
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowlist_suppresses_and_is_tracked() {
        let cfg = LintConfig::from_toml_str(
            "[R1]\nallow1 = \"src/wall.rs -- profiler file, wall-clock by design\"\n",
        )
        .unwrap();
        let src = "fn t() { let t0 = Instant::now(); }";
        assert!(lint_source("src/wall.rs", src, &cfg).is_empty());
        let none = LintConfig::default_config();
        assert_eq!(lint_source("src/wall.rs", src, &none).len(), 1);
    }

    #[test]
    fn json_shape_is_stable() {
        let report = LintReport {
            findings: vec![Finding {
                rule: "R1",
                path: "src/x.rs".into(),
                line: 3,
                message: "m".into(),
            }],
            files: vec!["src/x.rs".into()],
            used_allows: vec![],
            unused_allows: vec![],
        };
        let j = report.to_json();
        assert_eq!(j.get("version").and_then(|v| v.as_f64()), Some(1.0));
        let f = &j.get("findings").and_then(|f| f.as_arr()).unwrap()[0];
        for key in ["rule", "path", "line", "message"] {
            assert!(f.get(key).is_some(), "missing {key}");
        }
    }
}
