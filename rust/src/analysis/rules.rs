//! The rule catalog for `c2dfb lint` (docs/LINT.md).
//!
//! Each rule protects one documented runtime contract by refusing the
//! source-level constructs that can break it, *before* anything runs:
//!
//! | id | name                   | contract it protects                     |
//! |----|------------------------|------------------------------------------|
//! | R1 | no-wall-clock          | bit-identical replays & byte-stable traces (docs/OBS.md, docs/SWEEP.md) |
//! | R2 | no-unordered-iteration | deterministic iteration everywhere (HashMap/HashSet banned; BTreeMap orders by construction) |
//! | R3 | panic-free-decode      | hostile bytes never panic the decode/request-parsing paths (docs/SERVE.md) |
//! | R4 | safety-comments        | every `unsafe` carries a `// SAFETY:` argument |
//! | R5 | rng-discipline         | all randomness flows through the crate's seeded `Rng` (docs/SWEEP.md seed contract) |
//! | R6 | no-wall-keys           | the `c2dfb trace` "no key containing wall" check, applied statically at the emit sites (docs/OBS.md) |
//!
//! Rules match the token stream from [`crate::analysis::lexer`], so they
//! never fire inside string literals, char literals, comments, or raw
//! strings — and `#[cfg(test)]`/`#[test]` items are skipped entirely
//! (the contracts bind shipped code; tests exercise panics on purpose).

use super::lexer::{Tok, TokKind};

/// One reported violation.
#[derive(Clone, Debug, PartialEq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub message: String,
}

/// Static rule metadata (rendered by `--format json` and docs tooling).
pub struct RuleInfo {
    pub id: &'static str,
    pub name: &'static str,
    pub contract: &'static str,
}

pub const RULES: [RuleInfo; 6] = [
    RuleInfo {
        id: "R1",
        name: "no-wall-clock",
        contract: "deterministic modules never read the wall clock (docs/OBS.md)",
    },
    RuleInfo {
        id: "R2",
        name: "no-unordered-iteration",
        contract: "no HashMap/HashSet in deterministic modules; BTreeMap orders by construction (docs/SWEEP.md)",
    },
    RuleInfo {
        id: "R3",
        name: "panic-free-decode",
        contract: "hostile bytes return Err, never panic (docs/SERVE.md)",
    },
    RuleInfo {
        id: "R4",
        name: "safety-comments",
        contract: "every unsafe block argues its soundness in a // SAFETY: comment",
    },
    RuleInfo {
        id: "R5",
        name: "rng-discipline",
        contract: "all randomness is derived from the run seed via the crate Rng (docs/SWEEP.md)",
    },
    RuleInfo {
        id: "R6",
        name: "no-wall-keys",
        contract: "no trace key contains 'wall' (the c2dfb trace schema check, statically)",
    },
];

/// Keywords that can legitimately precede `[` without forming an index
/// expression (`&mut [f32]`, `for x in [..]`, `dyn [..]`, …).
const KEYWORDS: [&str; 35] = [
    "as", "async", "await", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "self", "Self", "static", "struct", "super", "trait",
    "type", "unsafe", "use", "where",
];

/// Compute which tokens sit inside `#[cfg(test)]` / `#[test]` items (the
/// attribute itself, the item header, and its brace-delimited body) so
/// rules can skip them.
pub fn test_skip_mask(toks: &[Tok]) -> Vec<bool> {
    let mut skip = vec![false; toks.len()];
    let sig: Vec<usize> = (0..toks.len())
        .filter(|&i| toks[i].kind != TokKind::Comment)
        .collect();
    let mut s = 0usize;
    while s < sig.len() {
        if is_punct(toks, &sig, s, '#') && is_punct(toks, &sig, s + 1, '[') {
            // Collect the attribute's identifiers up to the matching ']'.
            let mut depth = 0usize;
            let mut idents: Vec<&str> = Vec::new();
            let mut e = s + 1;
            while e < sig.len() {
                match &toks[sig[e]].kind {
                    TokKind::Punct('[') => depth += 1,
                    TokKind::Punct(']') => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    TokKind::Ident => idents.push(&toks[sig[e]].text),
                    _ => {}
                }
                e += 1;
            }
            let is_test_attr = idents.as_slice() == ["test"]
                || (idents.first().copied() == Some("cfg")
                    && idents.iter().any(|i| *i == "test")
                    && !idents.iter().any(|i| *i == "not"));
            if is_test_attr && e < sig.len() {
                // Skip the attribute, any stacked attributes, and the
                // following item: to the matching `}` of its first body
                // brace, or to a top-level `;` for brace-less items.
                let start_tok = sig[s];
                let mut k = e + 1;
                while k + 1 < sig.len()
                    && is_punct(toks, &sig, k, '#')
                    && is_punct(toks, &sig, k + 1, '[')
                {
                    let mut d = 0usize;
                    while k < sig.len() {
                        match &toks[sig[k]].kind {
                            TokKind::Punct('[') => d += 1,
                            TokKind::Punct(']') => {
                                d -= 1;
                                if d == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                    k += 1;
                }
                let mut brace = 0usize;
                let mut entered = false;
                while k < sig.len() {
                    match &toks[sig[k]].kind {
                        TokKind::Punct('{') => {
                            brace += 1;
                            entered = true;
                        }
                        TokKind::Punct('}') => {
                            brace = brace.saturating_sub(1);
                            if entered && brace == 0 {
                                break;
                            }
                        }
                        TokKind::Punct(';') if !entered => break,
                        _ => {}
                    }
                    k += 1;
                }
                let end_tok = if k < sig.len() { sig[k] } else { toks.len() - 1 };
                for slot in skip.iter_mut().take(end_tok + 1).skip(start_tok) {
                    *slot = true;
                }
                s = k + 1;
                continue;
            }
        }
        s += 1;
    }
    skip
}

/// Run every rule that `applies` says is in scope for `path` over the
/// token stream; allowlisting happens in the caller.
pub fn run_rules(
    path: &str,
    toks: &[Tok],
    applies: impl Fn(&str) -> bool,
) -> Vec<Finding> {
    let skip = test_skip_mask(toks);
    // Significant (non-comment, non-skipped) token indices, for
    // adjacency checks.
    let sig: Vec<usize> = (0..toks.len())
        .filter(|&i| toks[i].kind != TokKind::Comment && !skip[i])
        .collect();
    let mut out = Vec::new();
    let f = |rule: &'static str, line: u32, message: String| Finding {
        rule,
        path: path.to_string(),
        line,
        message,
    };

    for (s, &i) in sig.iter().enumerate() {
        let t = &toks[i];
        let prev = s.checked_sub(1).map(|p| &toks[sig[p]]);
        let next = sig.get(s + 1).map(|&n| &toks[n]);
        match &t.kind {
            TokKind::Ident => {
                let name = t.text.as_str();
                if applies("R1") && matches!(name, "Instant" | "SystemTime") {
                    out.push(f(
                        "R1",
                        t.line,
                        format!("wall-clock type `{name}` in a deterministic module"),
                    ));
                }
                if applies("R1")
                    && name == "elapsed"
                    && prev_is_punct(prev, '.')
                    && next_is_punct(next, '(')
                {
                    out.push(f("R1", t.line, "wall-clock read `.elapsed()`".to_string()));
                }
                if applies("R2") && matches!(name, "HashMap" | "HashSet") {
                    out.push(f(
                        "R2",
                        t.line,
                        format!(
                            "`{name}` in a deterministic module: iteration order is \
                             randomized per process; use BTreeMap/BTreeSet or allowlist \
                             with an order-insensitivity argument"
                        ),
                    ));
                }
                if applies("R3")
                    && matches!(name, "unwrap" | "expect")
                    && prev_is_punct(prev, '.')
                    && next_is_punct(next, '(')
                {
                    out.push(f(
                        "R3",
                        t.line,
                        format!("`.{name}()` on a hostile-input path; return Err instead"),
                    ));
                }
                if applies("R3")
                    && matches!(name, "panic" | "todo" | "unimplemented")
                    && next_is_punct(next, '!')
                {
                    out.push(f(
                        "R3",
                        t.line,
                        format!("`{name}!` on a hostile-input path; return Err instead"),
                    ));
                }
                if applies("R5")
                    && matches!(
                        name,
                        "thread_rng" | "OsRng" | "StdRng" | "SmallRng" | "from_entropy" | "getrandom"
                    )
                {
                    out.push(f(
                        "R5",
                        t.line,
                        format!("foreign RNG `{name}`: all randomness must flow through the crate's seeded Rng"),
                    ));
                }
                if applies("R5")
                    && name == "rand"
                    && next_is_punct(next, ':')
                    && sig.get(s + 2).map(|&n| &toks[n].kind) == Some(&TokKind::Punct(':'))
                {
                    out.push(f(
                        "R5",
                        t.line,
                        "`rand::` path: the rand crate is banned; use the crate's seeded Rng"
                            .to_string(),
                    ));
                }
                if applies("R4") && name == "unsafe" && !has_safety_comment(toks, i) {
                    out.push(f(
                        "R4",
                        t.line,
                        "`unsafe` without a preceding `// SAFETY:` comment arguing soundness"
                            .to_string(),
                    ));
                }
            }
            TokKind::Punct('[') if applies("R3") => {
                let indexing = match prev.map(|p| &p.kind) {
                    Some(TokKind::Ident) => {
                        !KEYWORDS.contains(&prev.map(|p| p.text.as_str()).unwrap_or(""))
                    }
                    Some(TokKind::Punct(')')) | Some(TokKind::Punct(']')) => true,
                    _ => false,
                };
                if indexing {
                    out.push(f(
                        "R3",
                        t.line,
                        "slice/array index expression on a hostile-input path; use .get()"
                            .to_string(),
                    ));
                }
            }
            TokKind::Str if applies("R6") => {
                let lower = t.text.to_ascii_lowercase();
                if lower.contains("wall")
                    && (t.text.contains("\\\":") || t.text.contains("\":"))
                {
                    out.push(f(
                        "R6",
                        t.line,
                        "string literal builds a trace key containing \"wall\"; the \
                         deterministic trace schema rejects it at runtime — remove it here"
                            .to_string(),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}

fn is_punct(toks: &[Tok], sig: &[usize], s: usize, c: char) -> bool {
    sig.get(s)
        .map(|&i| toks[i].kind == TokKind::Punct(c))
        .unwrap_or(false)
}

fn prev_is_punct(prev: Option<&Tok>, c: char) -> bool {
    prev.map(|p| p.kind == TokKind::Punct(c)).unwrap_or(false)
}

fn next_is_punct(next: Option<&Tok>, c: char) -> bool {
    next.map(|p| p.kind == TokKind::Punct(c)).unwrap_or(false)
}

/// R4: walk back over the comment run immediately preceding the `unsafe`
/// token; any comment in that run whose text (after stripping doc-slash
/// and bang decoration) starts with `SAFETY:` satisfies the rule.
fn has_safety_comment(toks: &[Tok], unsafe_idx: usize) -> bool {
    // Walk the contiguous comment run immediately above the `unsafe`:
    // each comment must sit within 2 lines of the code/comment below it
    // (so a blank line inside the run is tolerated, but a comment
    // paragraph separated from the block by other code never counts).
    // The run may be arbitrarily long — a thorough SAFETY argument is
    // exactly what R4 wants to encourage.
    let mut below_line = toks.get(unsafe_idx).map(|t| t.line).unwrap_or(0);
    let mut j = unsafe_idx;
    while j > 0 {
        j -= 1;
        match toks.get(j).map(|t| &t.kind) {
            Some(TokKind::Comment) => {
                let tok = &toks[j];
                // A block comment spans lines; measure adjacency from
                // where it ends, not where it starts.
                let end_line = tok.line + tok.text.matches('\n').count() as u32;
                if below_line.saturating_sub(end_line) > 2 {
                    return false;
                }
                below_line = tok.line;
                let t = tok.text.trim_start_matches(['/', '!', '*']).trim_start();
                // A `--fix-safety-stubs` placeholder is not an argument;
                // the rule keeps failing until the FIXME is replaced.
                if t.starts_with("SAFETY:") && !t.contains("FIXME(c2dfb lint)") {
                    return true;
                }
            }
            _ => return false,
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::lex;

    fn run_all(src: &str) -> Vec<Finding> {
        run_rules("src/t.rs", &lex(src), |_| true)
    }

    #[test]
    fn r1_fires_on_instant_but_not_in_strings_or_comments() {
        let fs = run_all("fn t() { let t0 = Instant::now(); }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "R1");
        assert_eq!(fs[0].line, 1);
        assert!(run_all("// Instant::now()\nfn t() {}").is_empty());
        assert!(run_all("fn t() -> &'static str { \"Instant SystemTime\" }").is_empty());
    }

    #[test]
    fn r3_indexing_vs_types_and_literals() {
        let fs = run_all("fn t(b: &[u8]) -> u8 { b[0] }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "R3");
        // Slice types, array literals, attributes, and vec![] are fine.
        assert!(run_all("#[derive(Debug)]\nfn t(x: &mut [f32]) -> Vec<u8> { vec![1, 2] }")
            .is_empty());
        assert!(run_all("fn t() { for _ in [1, 2] {} }").is_empty());
    }

    #[test]
    fn r4_safety_comment_satisfies() {
        let bad = "fn t(p: *const u8) -> u8 { unsafe { *p } }";
        assert_eq!(run_all(bad).len(), 1);
        let good = "fn t(p: *const u8) -> u8 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}";
        assert!(run_all(good).is_empty());
        let far = "fn t(p: *const u8) -> u8 {\n    // SAFETY: too far away.\n\n\n\n\n\n\n\n    unsafe { *p }\n}";
        assert_eq!(run_all(far).len(), 1);
        // A long contiguous comment run qualifies however many lines the
        // SAFETY argument takes (the daemon signal handler's is ~11).
        let long = format!(
            "fn t(p: *const u8) -> u8 {{\n    // SAFETY: a thorough argument:\n{}    unsafe {{ *p }}\n}}",
            "    // - because of many careful reasons.\n".repeat(10)
        );
        assert!(run_all(&long).is_empty());
        // A --fix-safety-stubs placeholder does not count as an argument.
        let stub = "fn t(p: *const u8) -> u8 {\n    // SAFETY: FIXME(c2dfb lint): argue why this unsafe is sound.\n    unsafe { *p }\n}";
        assert_eq!(run_all(stub).len(), 1);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { let x = Instant::now(); x.unwrap(); }\n}\nfn live() { let _ = Instant::now(); }";
        let fs = run_all(src);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].line, 5);
        // #[cfg(not(test))] must NOT be skipped.
        let fs = run_all("#[cfg(not(test))]\nfn t() { Instant::now(); }");
        assert_eq!(fs.len(), 1);
        // A cfg(test) use statement skips only to the semicolon.
        let fs = run_all("#[cfg(test)]\nuse foo::bar;\nfn t() { Instant::now(); }");
        assert_eq!(fs.len(), 1);
    }

    #[test]
    fn r6_matches_key_literals_only() {
        let fs = run_all("fn t(b: &mut String) { b.push_str(\",\\\"wall_s\\\":\"); }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "R6");
        // Prose mentioning wall without a key shape is fine.
        assert!(run_all("fn t() -> &'static str { \"wall-clock profile\" }").is_empty());
    }

    #[test]
    fn r5_rand_paths() {
        let fs = run_all("fn t() { let mut r = thread_rng(); }");
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "R5");
        let fs = run_all("fn t() { let x = rand::random::<f64>(); }");
        assert_eq!(fs.len(), 1);
        // An ordinary identifier merely named rand does not fire.
        assert!(run_all("fn t(rand: u64) -> u64 { rand }").is_empty());
    }
}
