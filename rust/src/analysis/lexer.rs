//! A small hand-rolled Rust lexer for the static-analysis pass.
//!
//! The goal is NOT a full grammar — only a token stream faithful enough
//! that rules never fire inside string literals, char/byte literals,
//! comments, or raw strings, and can reason about adjacency ("`[` right
//! after an identifier is an index expression", "`unsafe` preceded by a
//! `// SAFETY:` comment").  Everything the rules in
//! [`crate::analysis::rules`] match is an [`Ident`], [`Punct`] or
//! [`Str`] token; comments are kept in the stream (as [`Comment`]) so
//! the safety-comment rule can see them, and every token carries its
//! 1-based start line for reporting.
//!
//! [`Ident`]: TokKind::Ident
//! [`Punct`]: TokKind::Punct
//! [`Str`]: TokKind::Str
//! [`Comment`]: TokKind::Comment

/// Token classification.  `Str` holds the raw source text between the
/// delimiters (escapes NOT processed — rules match on source bytes);
/// `Comment` holds the text after `//` / between `/* */`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    /// String or byte-string literal (cooked or raw).
    Str,
    /// Char or byte literal (`'x'`, `b'\n'`).
    Char,
    Num,
    /// A single punctuation character.
    Punct(char),
    /// Line or block comment (doc comments included).
    Comment,
    /// A lifetime (`'a`, `'static`).
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    /// Source text: the identifier itself, the literal's inner text, the
    /// comment body, or the punctuation character.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Lex `src` into a token stream.  Unknown bytes are skipped (they can
/// only occur in pathological input; this lexer is for OUR source tree,
/// and the self-test fixtures prove the cases the rules depend on).
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { b: src.as_bytes(), src, i: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer<'a> {
    b: &'a [u8],
    src: &'a str,
    i: usize,
    line: u32,
    out: Vec<Tok>,
}

impl<'a> Lexer<'a> {
    fn run(mut self) -> Vec<Tok> {
        while self.i < self.b.len() {
            let c = self.b[self.i];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.i += 1;
                }
                c if c.is_ascii_whitespace() => self.i += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.cooked_string(false),
                b'\'' => self.char_or_lifetime(),
                b'r' | b'b' if self.raw_or_byte_prefix() => {}
                c if is_ident_start(c) => self.ident(),
                c if c.is_ascii_digit() => self.number(),
                c if c.is_ascii() => {
                    self.push(TokKind::Punct(c as char), self.i, self.i + 1, self.line);
                    self.i += 1;
                }
                // Non-ASCII outside strings/comments: skip the byte.
                _ => self.i += 1,
            }
        }
        self.out
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.b.get(self.i + ahead).copied()
    }

    fn push(&mut self, kind: TokKind, start: usize, end: usize, line: u32) {
        let text = self.src.get(start..end).unwrap_or_default().to_string();
        self.out.push(Tok { kind, text, line });
    }

    fn line_comment(&mut self) {
        let start = self.i + 2;
        let line = self.line;
        let mut j = start;
        while j < self.b.len() && self.b[j] != b'\n' {
            j += 1;
        }
        self.push(TokKind::Comment, start, j, line);
        self.i = j;
    }

    fn block_comment(&mut self) {
        let start = self.i + 2;
        let line = self.line;
        let mut depth = 1usize;
        let mut j = start;
        while j < self.b.len() && depth > 0 {
            if self.b[j] == b'\n' {
                self.line += 1;
                j += 1;
            } else if self.b[j] == b'/' && self.b.get(j + 1) == Some(&b'*') {
                depth += 1;
                j += 2;
            } else if self.b[j] == b'*' && self.b.get(j + 1) == Some(&b'/') {
                depth -= 1;
                j += 2;
            } else {
                j += 1;
            }
        }
        let end = j.saturating_sub(2).max(start);
        self.push(TokKind::Comment, start, end, line);
        self.i = j;
    }

    /// Cooked (escape-processing) string starting at the current `"`.
    /// `byte` marks `b"..."` — lexed identically.
    fn cooked_string(&mut self, _byte: bool) {
        let line = self.line;
        let start = self.i + 1;
        let mut j = start;
        while j < self.b.len() {
            match self.b[j] {
                b'\\' => j += 2,
                b'"' => break,
                b'\n' => {
                    self.line += 1;
                    j += 1;
                }
                _ => j += 1,
            }
        }
        self.push(TokKind::Str, start, j.min(self.b.len()), line);
        self.i = (j + 1).min(self.b.len());
    }

    /// Raw string `r"…"`, `r#"…"#`, … starting at the current `"` with
    /// `hashes` trailing `#`s expected after the closing quote.
    fn raw_string(&mut self, hashes: usize) {
        let line = self.line;
        let start = self.i + 1;
        let mut j = start;
        while j < self.b.len() {
            if self.b[j] == b'\n' {
                self.line += 1;
                j += 1;
                continue;
            }
            if self.b[j] == b'"' {
                let close = &self.b[j + 1..];
                if close.len() >= hashes && close.iter().take(hashes).all(|&c| c == b'#') {
                    break;
                }
            }
            j += 1;
        }
        self.push(TokKind::Str, start, j.min(self.b.len()), line);
        self.i = (j + 1 + hashes).min(self.b.len());
    }

    /// Handle `r"`, `r#"`, `br"`, `b"`, `b'`, and raw identifiers
    /// (`r#ident`).  Returns true when the current position was consumed
    /// as one of those; false lets the caller fall through to a plain
    /// identifier starting with `r`/`b`.
    fn raw_or_byte_prefix(&mut self) -> bool {
        let c = self.b[self.i];
        let mut j = self.i + 1;
        if c == b'b' && self.b.get(j) == Some(&b'\'') {
            // Byte literal b'x'.
            self.i += 1;
            self.char_literal();
            return true;
        }
        if c == b'b' && self.b.get(j) == Some(&b'r') {
            j += 1;
        }
        let mut hashes = 0usize;
        while self.b.get(j) == Some(&b'#') {
            hashes += 1;
            j += 1;
        }
        match self.b.get(j) {
            Some(&b'"') if c == b'b' && self.b.get(self.i + 1) == Some(&b'"') => {
                // b"..." cooked byte string.
                self.i = j;
                self.cooked_string(true);
                true
            }
            Some(&b'"') if hashes > 0 || matches!((c, self.b.get(self.i + 1)), (b'r', Some(&b'"'))) || (c == b'b' && self.b.get(self.i + 1) == Some(&b'r')) => {
                // r"...", r#"..."#, br"...", br#"..."#.
                self.i = j;
                self.raw_string(hashes);
                true
            }
            Some(&n) if c == b'r' && hashes == 1 && is_ident_start(n) => {
                // Raw identifier r#ident.
                self.i = j;
                self.ident();
                true
            }
            _ => false,
        }
    }

    /// Char literal starting at the current `'` (after any `b` prefix).
    fn char_literal(&mut self) {
        let line = self.line;
        let start = self.i + 1;
        let mut j = start;
        if self.b.get(j) == Some(&b'\\') {
            j += 2;
        } else if j < self.b.len() {
            j += 1;
            // Multi-byte UTF-8 scalar: advance to the closing quote.
            while j < self.b.len() && self.b[j] != b'\'' {
                j += 1;
            }
        }
        // Escapes like \u{1F600} span to the closing quote.
        while j < self.b.len() && self.b[j] != b'\'' {
            j += 1;
        }
        self.push(TokKind::Char, start, j.min(self.b.len()), line);
        self.i = (j + 1).min(self.b.len());
    }

    /// Disambiguate `'a` (lifetime) from `'a'` (char literal).
    fn char_or_lifetime(&mut self) {
        match (self.peek(1), self.peek(2)) {
            // '\... is always a char literal.
            (Some(b'\\'), _) => self.char_literal(),
            // 'x' — char literal.
            (Some(_), Some(b'\'')) => self.char_literal(),
            // Non-ASCII after the quote: multi-byte char literal.
            (Some(n), _) if !n.is_ascii() => self.char_literal(),
            // 'ident not followed by a quote: lifetime.
            (Some(n), _) if is_ident_start(n) => {
                let line = self.line;
                let start = self.i + 1;
                let mut j = start;
                while j < self.b.len() && is_ident_cont(self.b[j]) {
                    j += 1;
                }
                self.push(TokKind::Lifetime, start, j, line);
                self.i = j;
            }
            _ => {
                // Stray quote; emit as punctuation and move on.
                self.push(TokKind::Punct('\''), self.i, self.i + 1, self.line);
                self.i += 1;
            }
        }
    }

    fn ident(&mut self) {
        let start = self.i;
        let mut j = start;
        while j < self.b.len() && is_ident_cont(self.b[j]) {
            j += 1;
        }
        self.push(TokKind::Ident, start, j, self.line);
        self.i = j;
    }

    /// Numbers: decimal/hex/octal/binary ints, floats, exponents, type
    /// suffixes.  A `.` is consumed only when a digit follows, so range
    /// expressions (`0..n`) never swallow the identifier after them.
    fn number(&mut self) {
        let start = self.i;
        let mut j = start;
        if self.b[j] == b'0'
            && matches!(self.b.get(j + 1), Some(&b'x') | Some(&b'o') | Some(&b'b'))
        {
            j += 2;
            while j < self.b.len()
                && (self.b[j].is_ascii_hexdigit() || self.b[j] == b'_')
            {
                j += 1;
            }
        } else {
            while j < self.b.len() && (self.b[j].is_ascii_digit() || self.b[j] == b'_') {
                j += 1;
            }
            if self.b.get(j) == Some(&b'.')
                && self.b.get(j + 1).is_some_and(|c| c.is_ascii_digit())
            {
                j += 1;
                while j < self.b.len() && (self.b[j].is_ascii_digit() || self.b[j] == b'_') {
                    j += 1;
                }
            }
            if matches!(self.b.get(j), Some(&b'e') | Some(&b'E')) {
                let mut k = j + 1;
                if matches!(self.b.get(k), Some(&b'+') | Some(&b'-')) {
                    k += 1;
                }
                if self.b.get(k).is_some_and(|c| c.is_ascii_digit()) {
                    j = k;
                    while j < self.b.len() && self.b[j].is_ascii_digit() {
                        j += 1;
                    }
                }
            }
        }
        // Type suffix (u32, f64, usize, …).
        while j < self.b.len() && is_ident_cont(self.b[j]) {
            j += 1;
        }
        self.push(TokKind::Num, start, j, self.line);
        self.i = j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_strings_comments() {
        let toks = kinds(r#"let x = "Instant"; // Instant"#);
        assert_eq!(toks[0], (TokKind::Ident, "let".to_string()));
        assert_eq!(toks[1], (TokKind::Ident, "x".to_string()));
        assert_eq!(toks[2], (TokKind::Punct('='), "=".to_string()));
        assert_eq!(toks[3], (TokKind::Str, "Instant".to_string()));
        assert_eq!(toks[4], (TokKind::Punct(';'), ";".to_string()));
        assert_eq!(toks[5], (TokKind::Comment, " Instant".to_string()));
    }

    #[test]
    fn raw_strings_and_raw_idents() {
        let toks = kinds(r##"let s = r#"a "quoted" HashMap"#;"##);
        assert!(toks
            .iter()
            .any(|(k, t)| *k == TokKind::Str && t.contains("HashMap")));
        assert!(!toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "HashMap"));
        let toks = kinds("r#type");
        assert_eq!(toks, vec![(TokKind::Ident, "type".to_string())]);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let toks = kinds(r#"m(b"\r\n\r\n", b' ', b'[')"#);
        assert!(toks.iter().any(|(k, _)| *k == TokKind::Str));
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 2);
        // The '[' inside the byte char must NOT become punctuation.
        assert!(!toks.iter().any(|(k, _)| *k == TokKind::Punct('[')));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|(k, _)| *k == TokKind::Char).count(), 1);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let toks = kinds("for i in 0..rounds { a[i] = 1.5e-3f64; }");
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Ident && t == "rounds"));
        assert!(toks.iter().any(|(k, t)| *k == TokKind::Num && t == "1.5e-3f64"));
    }

    #[test]
    fn nested_block_comments() {
        let toks = kinds("/* outer /* inner */ still */ ident");
        assert_eq!(toks.len(), 2);
        assert_eq!(toks[1], (TokKind::Ident, "ident".to_string()));
    }

    #[test]
    fn lines_are_tracked() {
        let toks = lex("a\nb\n\"s\"\n// c");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3);
        assert_eq!(toks[3].line, 4);
    }
}
