//! Decentralized optimization building blocks:
//!
//! * [`refpoint`] — the paper's reference-point compressed consensus state
//!   (Algorithm 2's d̂ / ŝ bookkeeping, including the neighbour-weighted
//!   accumulator (d̂)_w so only residuals ever cross the wire).
//! * [`tracking`] — plain (uncompressed) gradient tracking, used by the
//!   outer loop and the baselines.
//! * [`inner`] — the `IN` procedure (Algorithm 2) over all nodes, plus the
//!   naive-compression variant used by the C²DFB(nc) ablation.

pub mod inner;
pub mod refpoint;
pub mod tracking;

pub use inner::{
    run_inner, run_inner_naive, run_inner_naive_with, run_inner_with, GradFn, InnerConfig,
    InnerState,
};
pub use refpoint::RefPoint;
pub use tracking::DenseTracker;
