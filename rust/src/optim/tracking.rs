//! Plain (uncompressed) gradient tracking, used by the C²DFB outer loop
//! (Algorithm 1's s_x) and by the dense baselines.
//!
//! Update: `s_i ← s_i + γ Σ_j w_ij (s_j − s_i) + u_i^{new} − u_i^{old}`.
//! Invariant (Proposition 4): the node average of the trackers always
//! equals the node average of the latest gradients.
//!
//! The tracker state lives in contiguous [`NodeBlock`] matrices and the
//! gossip mix runs in place through
//! [`Transport::mix_paid_into`](crate::collective::Transport::mix_paid_into)
//! with tracker-owned scratch, so a steady-state update allocates nothing
//! (the incoming gradient batch is the caller's).  Generic over the
//! payload [`Scalar`] `S`; the dense fold is `kernels::add_diff`.

use crate::collective::{MixScratch, Transport};
use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::linalg::NodeBlock;

pub struct DenseTracker<S: Scalar = f32> {
    /// Per-node tracker s_i (contiguous m×d; index or `.row(i)` for views).
    pub s: NodeBlock<S>,
    /// Last gradient u_i folded in.
    prev_u: NodeBlock<S>,
    /// Reused mixing buffers.
    mix: MixScratch<S>,
}

impl<S: Scalar> DenseTracker<S> {
    /// Initialize with the first gradients: s_i⁰ = u_i⁰.
    pub fn new(u0: Vec<Vec<S>>) -> DenseTracker<S> {
        let s = NodeBlock::from_rows(&u0);
        DenseTracker { prev_u: s.clone(), s, mix: MixScratch::new() }
    }

    /// One tracking round: gossip-mix the trackers in place (PAID
    /// communication via `net`), then fold in the new gradients.
    ///
    /// Under a sampling mask only active rows fold `u_new − prev_u` and
    /// refresh `prev_u` — inactive rows of `u_new` are stale (the caller
    /// skipped those oracles) and must not enter the tracker.  The mix
    /// itself is already mask-aware through `mix_paid_into`.
    pub fn update<T: Transport>(&mut self, net: &mut T, gamma: f64, u_new: &[Vec<S>]) {
        net.mix_paid_into(gamma, &mut self.s, &mut self.mix);
        let mask = net.active();
        for i in 0..self.s.nrows() {
            if let Some(mask) = mask {
                if !mask[i] {
                    continue;
                }
            }
            kernels::add_diff(&u_new[i], self.prev_u.row(i), self.s.row_mut(i));
        }
        match mask {
            None => self.prev_u.copy_from_rows(u_new),
            Some(mask) => {
                for i in 0..self.s.nrows() {
                    if mask[i] {
                        self.prev_u.row_mut(i).copy_from_slice(&u_new[i]);
                    }
                }
            }
        }
    }

    /// Last gradient folded in for node `i`.  Under sampling, callers
    /// reuse this for nodes that skipped the current round's oracle (the
    /// update above then folds a zero difference for them).
    pub fn last_u(&self, i: usize) -> &[S] {
        self.prev_u.row(i)
    }

    /// Tracker consensus error ‖s − 1·s̄‖² (outer Lyapunov Ω₂).
    pub fn consensus_err_sq(&self) -> f64 {
        self.s.consensus_err_sq()
    }

    /// Mean tracker (≡ mean of latest gradients by the invariant).
    pub fn mean(&self) -> Vec<S> {
        self.s.mean_row()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::linalg;
    use crate::topology::{Graph, Topology};
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, m: usize, d: usize) -> Vec<Vec<f32>> {
        (0..m)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    /// Proposition 4: mean(s) == mean(latest u) after every update.
    #[test]
    fn tracker_mean_equals_gradient_mean() {
        let mut rng = Rng::new(1);
        let mut net = Network::new(Graph::build(Topology::Ring, 6));
        let u0 = rand_rows(&mut rng, 6, 5);
        let mut t = DenseTracker::new(u0);
        for _ in 0..7 {
            let u = rand_rows(&mut rng, 6, 5);
            t.update(&mut net, 0.5, &u);
            let su = linalg::mean_rows(&u);
            let ss = t.mean();
            for (a, b) in su.iter().zip(&ss) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    /// With constant gradients the trackers reach consensus at s̄ = ū.
    #[test]
    fn tracker_converges_with_static_gradients() {
        let mut rng = Rng::new(2);
        let mut net = Network::new(Graph::build(Topology::TwoHopRing, 8));
        let u = rand_rows(&mut rng, 8, 4);
        let mut t = DenseTracker::new(u.clone());
        for _ in 0..300 {
            t.update(&mut net, 0.8, &u);
        }
        let mean = linalg::mean_rows(&u);
        for s in t.s.rows() {
            for (a, b) in s.iter().zip(&mean) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
        assert!(t.consensus_err_sq() < 1e-5);
    }

    /// The tracking invariant is dtype-generic: at f64 the mean identity
    /// holds to near machine precision.
    #[test]
    fn tracker_invariant_at_f64() {
        let mut rng = Rng::new(9);
        let mut net = Network::new(Graph::build(Topology::Ring, 5));
        let u0: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..3).map(|_| rng.normal()).collect())
            .collect();
        let mut t = DenseTracker::new(u0);
        for _ in 0..5 {
            let u: Vec<Vec<f64>> = (0..5)
                .map(|_| (0..3).map(|_| rng.normal()).collect())
                .collect();
            t.update(&mut net, 0.5, &u);
            let su = linalg::mean_rows(&u);
            for (a, b) in su.iter().zip(&t.mean()) {
                assert!((a - b).abs() < 1e-12, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn tracking_pays_communication() {
        let mut rng = Rng::new(3);
        let mut net = Network::new(Graph::build(Topology::Ring, 4));
        let u = rand_rows(&mut rng, 4, 10);
        let mut t = DenseTracker::new(u.clone());
        t.update(&mut net, 0.5, &u);
        assert!(net.ledger.total_bytes > 0);
        assert_eq!(net.ledger.gossip_rounds, 1);
    }

    /// The in-place update is bit-identical to the allocating reference
    /// formulation (mix_paid + rebuild), per update and cumulatively.
    #[test]
    fn inplace_update_matches_allocating_reference() {
        let mut rng = Rng::new(4);
        let mut net = Network::new(Graph::build(Topology::Ring, 5));
        let mut net_ref = Network::new(Graph::build(Topology::Ring, 5));
        let u0 = rand_rows(&mut rng, 5, 7);
        let mut t = DenseTracker::new(u0.clone());
        let mut s_ref = u0.clone();
        let mut prev_ref = u0;
        for _ in 0..6 {
            let u = rand_rows(&mut rng, 5, 7);
            t.update(&mut net, 0.7, &u);
            let mixed = net_ref.mix_paid(0.7, &s_ref);
            s_ref = mixed;
            for i in 0..5 {
                for k in 0..7 {
                    s_ref[i][k] += u[i][k] - prev_ref[i][k];
                }
            }
            prev_ref = u.clone();
            assert_eq!(t.s.to_vecs(), s_ref, "tracker diverged from reference");
        }
        assert_eq!(net.ledger.total_bytes, net_ref.ledger.total_bytes);
    }

    /// Sampling: inactive tracker rows are frozen exactly (no mix drift,
    /// no stale-gradient fold), and an all-true mask is bit-identical to
    /// running unmasked.
    #[test]
    fn masked_update_freezes_inactive_rows() {
        use std::sync::Arc;
        let m = 6;
        let mask = Arc::new(vec![true, true, false, true, false, true]);
        let mut rng = Rng::new(5);
        let u0 = rand_rows(&mut rng, m, 4);
        let u1 = rand_rows(&mut rng, m, 4);

        let mut net = Network::new(Graph::build(Topology::Ring, m));
        net.set_active(Some(mask.clone()));
        let mut t = DenseTracker::new(u0.clone());
        t.update(&mut net, 0.6, &u1);
        for i in 0..m {
            if !mask[i] {
                assert_eq!(t.s.row(i), &u0[i][..], "inactive tracker row {i} moved");
            } else {
                assert_ne!(t.s.row(i), &u0[i][..], "active tracker row {i} frozen");
            }
        }

        let mut net_all = Network::new(Graph::build(Topology::Ring, m));
        net_all.set_active(Some(Arc::new(vec![true; m])));
        let mut t_all = DenseTracker::new(u0.clone());
        t_all.update(&mut net_all, 0.6, &u1);
        let mut net_none = Network::new(Graph::build(Topology::Ring, m));
        let mut t_none = DenseTracker::new(u0);
        t_none.update(&mut net_none, 0.6, &u1);
        assert_eq!(t_all.s.to_vecs(), t_none.s.to_vecs());
        assert_eq!(net_all.ledger.total_bytes, net_none.ledger.total_bytes);
    }
}
