//! Plain (uncompressed) gradient tracking, used by the C²DFB outer loop
//! (Algorithm 1's s_x) and by the dense baselines.
//!
//! Update: `s_i ← s_i + γ Σ_j w_ij (s_j − s_i) + u_i^{new} − u_i^{old}`.
//! Invariant (Proposition 4): the node average of the trackers always
//! equals the node average of the latest gradients.

use crate::collective::Transport;
use crate::linalg;

pub struct DenseTracker {
    /// Per-node tracker s_i.
    pub s: Vec<Vec<f32>>,
    /// Last gradient u_i folded in.
    prev_u: Vec<Vec<f32>>,
}

impl DenseTracker {
    /// Initialize with the first gradients: s_i⁰ = u_i⁰.
    pub fn new(u0: Vec<Vec<f32>>) -> DenseTracker {
        DenseTracker { s: u0.clone(), prev_u: u0 }
    }

    /// One tracking round: gossip-mix the trackers (PAID communication via
    /// `net`), then fold in the new gradients.
    pub fn update<T: Transport>(&mut self, net: &mut T, gamma: f64, u_new: &[Vec<f32>]) {
        let mixed = net.mix_paid(gamma, &self.s);
        self.s = mixed;
        for i in 0..self.s.len() {
            for k in 0..self.s[i].len() {
                self.s[i][k] += u_new[i][k] - self.prev_u[i][k];
            }
        }
        self.prev_u = u_new.to_vec();
    }

    /// Tracker consensus error ‖s − 1·s̄‖² (outer Lyapunov Ω₂).
    pub fn consensus_err_sq(&self) -> f64 {
        linalg::consensus_err_sq(&self.s)
    }

    /// Mean tracker (≡ mean of latest gradients by the invariant).
    pub fn mean(&self) -> Vec<f32> {
        linalg::mean_rows(&self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::topology::{Graph, Topology};
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, m: usize, d: usize) -> Vec<Vec<f32>> {
        (0..m)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    /// Proposition 4: mean(s) == mean(latest u) after every update.
    #[test]
    fn tracker_mean_equals_gradient_mean() {
        let mut rng = Rng::new(1);
        let mut net = Network::new(Graph::build(Topology::Ring, 6));
        let u0 = rand_rows(&mut rng, 6, 5);
        let mut t = DenseTracker::new(u0);
        for _ in 0..7 {
            let u = rand_rows(&mut rng, 6, 5);
            t.update(&mut net, 0.5, &u);
            let su = linalg::mean_rows(&u);
            let ss = t.mean();
            for (a, b) in su.iter().zip(&ss) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    /// With constant gradients the trackers reach consensus at s̄ = ū.
    #[test]
    fn tracker_converges_with_static_gradients() {
        let mut rng = Rng::new(2);
        let mut net = Network::new(Graph::build(Topology::TwoHopRing, 8));
        let u = rand_rows(&mut rng, 8, 4);
        let mut t = DenseTracker::new(u.clone());
        for _ in 0..300 {
            t.update(&mut net, 0.8, &u);
        }
        let mean = linalg::mean_rows(&u);
        for s in &t.s {
            for (a, b) in s.iter().zip(&mean) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
        assert!(t.consensus_err_sq() < 1e-5);
    }

    #[test]
    fn tracking_pays_communication() {
        let mut rng = Rng::new(3);
        let mut net = Network::new(Graph::build(Topology::Ring, 4));
        let u = rand_rows(&mut rng, 4, 10);
        let mut t = DenseTracker::new(u.clone());
        t.update(&mut net, 0.5, &u);
        assert!(net.ledger.total_bytes > 0);
        assert_eq!(net.ledger.gossip_rounds, 1);
    }
}
