//! Reference-point compressed consensus state (paper §4.3, Algorithm 2).
//!
//! Each node i maintains, for a consensus variable d:
//!
//! * `hat`  — its own reference point d̂_i (also known to its neighbours);
//! * `hat_w` — the neighbour-weighted accumulator (d̂_i)_w = Σ_{j∈N_i} w_ij d̂_j,
//!   maintained incrementally from received compressed residuals so the
//!   full d̂_j vectors never travel.
//!
//! Per step: the mixing term is `γ ((d̂)_w − sw·d̂_i)` with `sw = Σ_{j∈N_i} w_ij`;
//! after the local update the node transmits `Q(d_new − d̂_i)`, applies it to
//! its own `hat`, and every neighbour folds the same message into its
//! `hat_w` with weight w_ij.  Because the identical message updates both
//! sides, `(d̂_i)_w` stays exactly consistent with Σ w_ij d̂_j (the paper's
//! key invariant), and the global average follows the uncompressed
//! dynamics (Eq. 7).
//!
//! Generic over the payload [`Scalar`] `S`; the dense folds live in
//! [`crate::linalg::kernels`].

use crate::compress::Compressed;
use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct RefPoint<S: Scalar = f32> {
    pub hat: Vec<S>,
    pub hat_w: Vec<S>,
    /// Σ_{j∈N_i} w_ij (constant for a fixed topology; = 1 − w_ii).
    pub neighbor_weight_sum: S,
}

impl<S: Scalar> RefPoint<S> {
    pub fn new(dim: usize, neighbor_weight_sum: f64) -> RefPoint<S> {
        RefPoint {
            hat: vec![S::ZERO; dim],
            hat_w: vec![S::ZERO; dim],
            neighbor_weight_sum: S::from_f64(neighbor_weight_sum),
        }
    }

    /// The consensus mixing term `γ Σ_j w_ij (d̂_j − d̂_i)` evaluated from the
    /// accumulator: `γ (hat_w − sw · hat)`, added onto `out`.
    pub fn add_mix_term(&self, gamma: S, out: &mut [S]) {
        debug_assert_eq!(out.len(), self.hat.len());
        kernels::ref_mix_term(gamma, self.neighbor_weight_sum, &self.hat_w, &self.hat, out);
    }

    /// Residual to transmit this step: `d_new − d̂_i` (dense, pre-compression).
    pub fn residual(&self, d_new: &[S]) -> Vec<S> {
        let mut out = Vec::new();
        self.residual_into(d_new, &mut out);
        out
    }

    /// [`RefPoint::residual`] into a reusable buffer (the hot path;
    /// allocation-free once `out` has capacity).  `out` is overwritten.
    pub fn residual_into(&self, d_new: &[S], out: &mut Vec<S>) {
        debug_assert_eq!(d_new.len(), self.hat.len());
        out.clear();
        out.extend(d_new.iter().zip(&self.hat).map(|(&d, &h)| d - h));
    }

    /// Reset to zero reference points against a new neighbour weight sum
    /// (topology-epoch resync) without reallocating.
    pub fn reset(&mut self, neighbor_weight_sum: f64) {
        self.hat.fill(S::ZERO);
        self.hat_w.fill(S::ZERO);
        self.neighbor_weight_sum = S::from_f64(neighbor_weight_sum);
    }

    /// Fold the node's *own* transmitted message into its reference point:
    /// `d̂_i ← d̂_i + Q(residual)`.
    pub fn apply_own(&mut self, msg: &Compressed<S>) {
        msg.add_into(&mut self.hat);
    }

    /// Fold a *neighbour's* message into the weighted accumulator:
    /// `(d̂)_w ← (d̂)_w + w_ij · Q_j`.
    pub fn apply_neighbor(&mut self, weight: f64, msg: &Compressed<S>) {
        msg.add_scaled_into(S::from_f64(weight), &mut self.hat_w);
    }

    /// Compression error ‖d − d̂‖² (the inner-loop Lyapunov term Ω₁).
    pub fn compression_err_sq(&self, d: &[S]) -> f64 {
        kernels::dist_sq(d, &self.hat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, TopK};
    use crate::topology::{Graph, MixingMatrix, Topology};
    use crate::util::rng::Rng;

    /// With the identity compressor, after one exchange the accumulator
    /// must equal Σ_j w_ij d̂_j exactly.
    #[test]
    fn accumulator_matches_direct_sum_identity() {
        let g = Graph::build(Topology::Ring, 5);
        let w = MixingMatrix::metropolis(&g);
        let d = 7;
        let mut rng = Rng::new(1);
        let mut states: Vec<RefPoint<f32>> = (0..5)
            .map(|i| RefPoint::new(d, 1.0 - w.weight(i, i)))
            .collect();
        // Each node "has" a vector and sends its full residual (Q = id).
        let vecs: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let msgs: Vec<_> = (0..5)
            .map(|i| Identity.compress(&states[i].residual(&vecs[i]), &mut rng))
            .collect();
        for i in 0..5 {
            states[i].apply_own(&msgs[i]);
        }
        for i in 0..5 {
            for &(j, wij) in w.neighbors(i) {
                states[i].apply_neighbor(wij, &msgs[j]);
            }
        }
        // hat_j == vecs_j now; check hat_w_i == Σ w_ij vecs_j.
        for i in 0..5 {
            for k in 0..d {
                let direct: f64 = w
                    .neighbors(i)
                    .iter()
                    .map(|&(j, wij)| wij * vecs[j][k] as f64)
                    .sum();
                assert!((states[i].hat_w[k] as f64 - direct).abs() < 1e-5);
            }
        }
    }

    /// The invariant holds for ANY compressor: hat_w_i == Σ_j w_ij hat_j,
    /// because both sides are updated from the identical message.
    #[test]
    fn invariant_under_topk_many_steps() {
        let g = Graph::build(Topology::TwoHopRing, 6);
        let w = MixingMatrix::metropolis(&g);
        let d = 13;
        let mut rng = Rng::new(2);
        let q = TopK::new(0.3);
        let mut states: Vec<RefPoint<f32>> = (0..6)
            .map(|i| RefPoint::new(d, 1.0 - w.weight(i, i)))
            .collect();
        let mut vecs: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        for _step in 0..10 {
            // Drift the vectors, then run the residual protocol.
            for v in vecs.iter_mut() {
                for x in v.iter_mut() {
                    *x += rng.normal_f32(0.0, 0.1);
                }
            }
            let msgs: Vec<_> = (0..6)
                .map(|i| q.compress(&states[i].residual(&vecs[i]), &mut rng))
                .collect();
            for i in 0..6 {
                states[i].apply_own(&msgs[i]);
            }
            for i in 0..6 {
                for &(j, wij) in w.neighbors(i) {
                    states[i].apply_neighbor(wij, &msgs[j]);
                }
            }
            for i in 0..6 {
                for k in 0..d {
                    let direct: f64 = w
                        .neighbors(i)
                        .iter()
                        .map(|&(j, wij)| wij * states[j].hat[k] as f64)
                        .sum();
                    assert!(
                        (states[i].hat_w[k] as f64 - direct).abs() < 1e-4,
                        "invariant broken at node {i} coord {k}"
                    );
                }
            }
        }
    }

    /// The invariant machinery is dtype-generic: the same protocol holds
    /// at f64 with a tighter tolerance.
    #[test]
    fn invariant_holds_at_f64() {
        let g = Graph::build(Topology::Ring, 5);
        let w = MixingMatrix::metropolis(&g);
        let d = 9;
        let mut rng = Rng::new(7);
        let q = TopK::new(0.4);
        let mut states: Vec<RefPoint<f64>> = (0..5)
            .map(|i| RefPoint::new(d, 1.0 - w.weight(i, i)))
            .collect();
        let vecs: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..d).map(|_| rng.normal()).collect())
            .collect();
        let msgs: Vec<_> = (0..5)
            .map(|i| q.compress(&states[i].residual(&vecs[i]), &mut rng))
            .collect();
        for i in 0..5 {
            states[i].apply_own(&msgs[i]);
        }
        for i in 0..5 {
            for &(j, wij) in w.neighbors(i) {
                states[i].apply_neighbor(wij, &msgs[j]);
            }
        }
        for i in 0..5 {
            for k in 0..d {
                let direct: f64 = w
                    .neighbors(i)
                    .iter()
                    .map(|&(j, wij)| wij * states[j].hat[k])
                    .sum();
                assert!((states[i].hat_w[k] - direct).abs() < 1e-10);
            }
        }
    }

    /// With repeated compression of a FIXED target the reference point
    /// converges to it geometrically (contractive compressor property).
    #[test]
    fn reference_converges_to_target() {
        let d = 50;
        let mut rng = Rng::new(3);
        let q = TopK::new(0.2);
        let target: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut rp = RefPoint::<f32>::new(d, 0.5);
        let mut prev = f64::INFINITY;
        for _ in 0..60 {
            let msg = q.compress(&rp.residual(&target), &mut rng);
            rp.apply_own(&msg);
            let err = rp.compression_err_sq(&target);
            assert!(err <= prev + 1e-9);
            prev = err;
        }
        assert!(prev < 1e-6, "did not converge: {prev}");
    }

    #[test]
    fn mix_term_zero_at_consensus() {
        let mut rp = RefPoint::<f32>::new(4, 0.6);
        rp.hat = vec![2.0; 4];
        rp.hat_w = vec![1.2; 4]; // = 0.6 * 2.0 ⇒ neighbours agree
        let mut out = vec![0.0f32; 4];
        rp.add_mix_term(0.5, &mut out);
        for o in out {
            assert!(o.abs() < 1e-6);
        }
    }
}
