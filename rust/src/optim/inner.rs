//! The inner loop `IN` (Algorithm 2): K steps of compressed, gradient-
//! tracked decentralized gradient descent on a strongly-convex objective.
//!
//! Two variants:
//! * [`run_inner`] — the paper's reference-point protocol (compressed
//!   residuals for both the model and the tracker, implicit error
//!   compensation, Eq. 6–7).
//! * [`run_inner_naive`] — the C²DFB(nc) ablation: compress the parameters
//!   directly with local error feedback (classic error accumulation), no
//!   reference points.
//!
//! Inner state persists across outer rounds: Algorithm 1 passes
//! `(d̂_i^K)^t, (s_i^K)^t, (ŝ_i^K)^t` back into the next round's `IN` call
//! (warm start), which `InnerState` models.

use crate::collective::Network;
use crate::compress::Compressor;
use crate::optim::refpoint::RefPoint;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct InnerConfig {
    pub eta: f64,
    pub gamma: f64,
    pub k_steps: usize,
}

/// Per-variable persistent inner-loop state across outer rounds.
pub struct InnerState {
    /// Model reference points (d̂, (d̂)_w) per node.
    pub d_ref: Vec<RefPoint>,
    /// Tracker values s_i per node.
    pub s: Vec<Vec<f32>>,
    /// Tracker reference points (ŝ, (ŝ)_w) per node.
    pub s_ref: Vec<RefPoint>,
    /// Gradient folded into the tracker last (∇r_i^k).
    pub prev_grad: Vec<Vec<f32>>,
    initialized: bool,
    /// Naive-variant error-feedback accumulators (e_i) for d and s.
    err_d: Vec<Vec<f32>>,
    err_s: Vec<Vec<f32>>,
}

impl InnerState {
    pub fn new(net: &Network, dim: usize) -> InnerState {
        let m = net.m();
        let mk_refs = || {
            (0..m)
                .map(|i| RefPoint::new(dim, 1.0 - net.mixing.weight(i, i)))
                .collect::<Vec<_>>()
        };
        InnerState {
            d_ref: mk_refs(),
            s: vec![vec![0.0; dim]; m],
            s_ref: mk_refs(),
            prev_grad: vec![vec![0.0; dim]; m],
            initialized: false,
            err_d: vec![vec![0.0; dim]; m],
            err_s: vec![vec![0.0; dim]; m],
        }
    }
}

/// Run K steps of Algorithm 2 over all nodes.
///
/// `d` is the per-node variable (y or z), updated in place.  `grad(i, d_i)`
/// is the local first-order oracle ∇r_i; each call is counted by the
/// caller.  Communication (two compressed messages per node per step) is
/// paid through `net`.
pub fn run_inner(
    cfg: &InnerConfig,
    net: &mut Network,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: impl FnMut(usize, &[f32]) -> Vec<f32>,
) {
    let m = net.m();
    let dim = d[0].len();
    debug_assert_eq!(d.len(), m);

    // Tracker bootstrap on the very first call: s_i⁰ = ∇r_i(d_i⁰).  On
    // warm starts the tracker carries over and self-corrects through the
    // gradient-difference term.
    if !state.initialized {
        for i in 0..m {
            let g = grad(i, &d[i]);
            state.prev_grad[i] = g.clone();
            state.s[i] = g;
        }
        state.initialized = true;
    }

    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;

    for _k in 0..cfg.k_steps {
        // -- 1. model update: d ← d + γ((d̂)_w − sw·d̂) − η s  --------------
        for i in 0..m {
            state.d_ref[i].add_mix_term(gamma, &mut d[i]);
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        // -- 2. transmit Q(d_new − d̂); update d̂ and (d̂)_w  -----------------
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.d_ref[i].residual(&d[i]), rng))
            .collect();
        for i in 0..m {
            state.d_ref[i].apply_own(&msgs[i]);
        }
        // Clone neighbour weights up-front to avoid borrowing net twice.
        for i in 0..m {
            let nbrs: Vec<(usize, f64)> = net.mixing.neighbors(i).to_vec();
            for (j, wij) in nbrs {
                state.d_ref[i].apply_neighbor(wij, &msgs[j]);
            }
        }
        net.exchange(msgs); // pays bytes; payload already applied above

        // -- 3. tracker update: s ← s + γ((ŝ)_w − sw·ŝ) + ∇r^{new} − ∇r^{old}
        for i in 0..m {
            state.s_ref[i].add_mix_term(gamma, &mut state.s[i]);
            let g_new = grad(i, &d[i]);
            for ((sk, gn), go) in state.s[i]
                .iter_mut()
                .zip(&g_new)
                .zip(&state.prev_grad[i])
            {
                *sk += gn - go;
            }
            state.prev_grad[i] = g_new;
        }
        // -- 4. transmit Q(s_new − ŝ); update ŝ and (ŝ)_w  -----------------
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.s_ref[i].residual(&state.s[i]), rng))
            .collect();
        for i in 0..m {
            state.s_ref[i].apply_own(&msgs[i]);
        }
        for i in 0..m {
            let nbrs: Vec<(usize, f64)> = net.mixing.neighbors(i).to_vec();
            for (j, wij) in nbrs {
                state.s_ref[i].apply_neighbor(wij, &msgs[j]);
            }
        }
        net.exchange(msgs);
        let _ = dim;
    }
}

/// The C²DFB(nc) ablation: per step each node transmits `Q(d_i + e_i)`
/// (error-feedback compression of the raw parameter), neighbours mix with
/// the received compressed values.  Same message count/sizes as
/// [`run_inner`] but errors accumulate locally instead of being implicitly
/// shared — the paper's Fig. 3 shows this is slower and less stable.
pub fn run_inner_naive(
    cfg: &InnerConfig,
    net: &mut Network,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: impl FnMut(usize, &[f32]) -> Vec<f32>,
) {
    let m = net.m();
    if !state.initialized {
        for i in 0..m {
            let g = grad(i, &d[i]);
            state.prev_grad[i] = g.clone();
            state.s[i] = g;
        }
        state.initialized = true;
    }
    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;

    for _k in 0..cfg.k_steps {
        // Compress d with error feedback.
        let mut msgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = d[i]
                .iter()
                .zip(&state.err_d[i])
                .map(|(a, e)| a + e)
                .collect();
            let q = compressor.compress(&carry, rng);
            // e ← (d + e) − Q(d + e)
            let dense = q.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_d[i] = carry;
            msgs.push(q);
        }
        let inbox = net.exchange(msgs.clone());
        // d_i ← d_i + γ Σ w_ij (Q_j − Q_i) − η s_i
        for i in 0..m {
            let own = msgs[i].to_dense();
            for (sender, q) in &inbox[i] {
                let w = (gamma as f64 * net.mixing.weight(i, *sender)) as f32;
                let qd = q.to_dense();
                for k in 0..d[i].len() {
                    d[i][k] += w * (qd[k] - own[k]);
                }
            }
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        // Tracker: same naive scheme on s.
        let mut smsgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = state.s[i]
                .iter()
                .zip(&state.err_s[i])
                .map(|(a, e)| a + e)
                .collect();
            let q = compressor.compress(&carry, rng);
            let dense = q.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_s[i] = carry;
            smsgs.push(q);
        }
        let inbox = net.exchange(smsgs.clone());
        for i in 0..m {
            let own = smsgs[i].to_dense();
            let mut mixed = state.s[i].clone();
            for (sender, q) in &inbox[i] {
                let w = (gamma as f64 * net.mixing.weight(i, *sender)) as f32;
                let qd = q.to_dense();
                for k in 0..mixed.len() {
                    mixed[k] += w * (qd[k] - own[k]);
                }
            }
            let g_new = grad(i, &d[i]);
            for ((sk, gn), go) in mixed.iter_mut().zip(&g_new).zip(&state.prev_grad[i]) {
                *sk += gn - go;
            }
            state.prev_grad[i] = g_new;
            state.s[i] = mixed;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::linalg;
    use crate::topology::{Graph, Topology};

    /// Heterogeneous strongly-convex quadratics:
    /// r_i(d) = ½ aᵢ‖d − cᵢ‖² with global optimum d* = Σaᵢcᵢ / Σaᵢ.
    struct Quad {
        a: Vec<f32>,
        c: Vec<Vec<f32>>,
    }

    impl Quad {
        fn build(m: usize, dim: usize, seed: u64) -> Quad {
            let mut rng = Rng::new(seed);
            Quad {
                a: (0..m).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                c: (0..m)
                    .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect())
                    .collect(),
            }
        }

        fn grad(&self, i: usize, d: &[f32]) -> Vec<f32> {
            d.iter()
                .zip(&self.c[i])
                .map(|(x, c)| self.a[i] * (x - c))
                .collect()
        }

        fn optimum(&self, dim: usize) -> Vec<f32> {
            let asum: f32 = self.a.iter().sum();
            let mut out = vec![0.0f32; dim];
            for i in 0..self.a.len() {
                for k in 0..dim {
                    out[k] += self.a[i] * self.c[i][k] / asum;
                }
            }
            out
        }
    }

    fn run(
        compressor: &dyn Compressor,
        steps: usize,
        naive: bool,
    ) -> (f64, f64) {
        let m = 6;
        let dim = 8;
        let q = Quad::build(m, dim, 42);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(7);
        let cfg = InnerConfig { eta: 0.15, gamma: 0.6, k_steps: steps };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        let g = |i: usize, di: &[f32]| q.grad(i, di);
        if naive {
            run_inner_naive(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        } else {
            run_inner(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        }
        let opt = q.optimum(dim);
        let err: f64 = d
            .iter()
            .map(|di| {
                di.iter()
                    .zip(&opt)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>()
            })
            .sum();
        (err, linalg::consensus_err_sq(&d))
    }

    #[test]
    fn converges_uncompressed() {
        let (err, cons) = run(&Identity, 400, false);
        assert!(err < 1e-6, "optimality err {err}");
        assert!(cons < 1e-6, "consensus err {cons}");
    }

    #[test]
    fn converges_with_topk() {
        let (err, cons) = run(&TopK::new(0.25), 800, false);
        assert!(err < 1e-4, "optimality err {err}");
        assert!(cons < 1e-4, "consensus err {cons}");
    }

    /// Theorem 1 shape: error after 2K steps ≪ error after K steps
    /// (linear rate), measured on the compressed protocol.  Stops checking
    /// once the error hits the f32 noise floor.
    #[test]
    fn linear_rate_doubling_k() {
        let floor = 1e-9;
        let (e1, _) = run(&TopK::new(0.25), 25, false);
        let (e2, _) = run(&TopK::new(0.25), 50, false);
        let (e4, _) = run(&TopK::new(0.25), 100, false);
        println!("linear_rate: e25={e1:.3e} e50={e2:.3e} e100={e4:.3e}");
        if e2 > floor {
            assert!(e2 < e1 * 0.5, "{e2} !< {e1}/2");
        }
        if e4 > floor {
            assert!(e4 < e2 * 0.5, "{e4} !< {e2}/2");
        }
        assert!(e4 < 1e-5, "not converged after 100 steps: {e4}");
    }

    /// The naive variant still roughly works on easy quadratics but the
    /// reference-point protocol reaches a (weakly) better point for the
    /// same budget — and must never be catastrophically unstable here.
    #[test]
    fn refpoint_no_worse_than_naive() {
        let (e_ref, _) = run(&TopK::new(0.25), 300, false);
        let (e_nc, _) = run(&TopK::new(0.25), 300, true);
        assert!(e_ref.is_finite() && e_nc.is_finite());
        assert!(e_ref <= e_nc * 1.5, "ref {e_ref} vs naive {e_nc}");
    }

    /// Eq. 7: the node-average follows the uncompressed dynamics
    /// d̄ ← d̄ − η s̄ exactly, for any compressor.
    #[test]
    fn mean_follows_uncompressed_dynamics() {
        let m = 5;
        let dim = 6;
        let q = Quad::build(m, dim, 9);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(1);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i * k) as f32 * 0.1).collect())
            .collect();
        // Bootstrap tracker (first run_inner call does it internally, but we
        // need s̄ BEFORE the step to predict the mean).
        for i in 0..m {
            let g = q.grad(i, &d[i]);
            state.prev_grad[i] = g.clone();
            state.s[i] = g;
        }
        state.initialized = true;

        for _step in 0..5 {
            let mean_before = linalg::mean_rows(&d);
            let s_mean = linalg::mean_rows(&state.s);
            let g = |i: usize, di: &[f32]| q.grad(i, di);
            run_inner(&cfg, &mut net, &TopK::new(0.3), &mut rng, &mut state, &mut d, g);
            let mean_after = linalg::mean_rows(&d);
            for k in 0..dim {
                let predicted = mean_before[k] - cfg.eta as f32 * s_mean[k];
                assert!(
                    (mean_after[k] - predicted).abs() < 1e-4,
                    "coord {k}: {} vs {}",
                    mean_after[k],
                    predicted
                );
            }
        }
    }

    #[test]
    fn communication_is_compressed() {
        let m = 6;
        let dim = 1000;
        let q = Quad::build(m, dim, 3);
        let mut rng = Rng::new(2);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 5 };

        let mut net_dense = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_dense, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_dense, &Identity, &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let dense_bytes = net_dense.ledger.total_bytes;

        let mut net_topk = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_topk, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_topk, &TopK::new(0.1), &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let topk_bytes = net_topk.ledger.total_bytes;
        assert!(
            (topk_bytes as f64) < dense_bytes as f64 * 0.3,
            "{topk_bytes} vs {dense_bytes}"
        );
    }
}
