//! The inner loop `IN` (Algorithm 2): K steps of compressed, gradient-
//! tracked decentralized gradient descent on a strongly-convex objective.
//!
//! Two variants:
//! * [`run_inner`] — the paper's reference-point protocol (compressed
//!   residuals for both the model and the tracker, implicit error
//!   compensation, Eq. 6–7).
//! * [`run_inner_naive`] — the C²DFB(nc) ablation: compress the parameters
//!   directly with local error feedback (classic error accumulation), no
//!   reference points.
//!
//! Both are generic over [`Transport`] and over the payload [`Scalar`]
//! `S` (`f32` wire default, `f64` high precision — docs/DTYPE.md), and
//! consume what the transport *actually delivered*: on the synchronous
//! engine that is every neighbour's message (identical to the original
//! lockstep formulation); on the event engine, lost messages simply never
//! reach the reference points — the exact failure mode a real deployment
//! would see.
//!
//! **This is the communication hot path and it is allocation-free in
//! steady state.**  Every buffer a step needs — residual scratch, the
//! per-node [`Compressed`] message slots, the delivered-sender lists, and
//! the contiguous [`NodeBlock`] matrices backing `s`, `∇r` batches and the
//! error-feedback accumulators — lives in [`InnerState`] and is reused
//! across steps and outer rounds.  Messages travel by reference through
//! [`Transport::exchange_indices`], so no `Arc`/`Vec` churn per round
//! (`benches/inner_loop.rs` asserts zero heap allocations per steady-state
//! step with a serial in-place oracle; the pool-parallel oracle path
//! stages rows through the thread pool and is not allocation-free —
//! there, task-oracle allocations and thread fan-out dominate anyway).
//! The dense folds themselves (descent, gradient-difference, weighted
//! mixing) all run through [`crate::linalg::kernels`].
//!
//! Weight/epoch consistency: neighbour folds must use the mixing weights
//! the messages were *sent* under.  A topology schedule can tick in the
//! middle of an exchange (graph epochs advance per gossip round), so each
//! exchange snapshots the epoch first; if the epoch moved during the
//! exchange, the in-flight messages belong to a dead epoch — they are
//! dropped rather than folded with new-epoch weights, and the reference
//! points resync immediately.
//!
//! Gradient oracles go through [`GradFn`]: a serial closure, or a
//! `Sync` closure plus a [`NodePool`] to evaluate nodes concurrently.
//! Each step's oracle batch happens at a point where the evaluated
//! state is frozen, so parallel evaluation is bit-identical to serial.
//!
//! Inner state persists across outer rounds: Algorithm 1 passes
//! `(d̂_i^K)^t, (s_i^K)^t, (ŝ_i^K)^t` back into the next round's `IN` call
//! (warm start), which `InnerState` models.

use crate::collective::Transport;
use crate::compress::{Compressed, Compressor};
use crate::linalg::kernels;
use crate::linalg::scalar::Scalar;
use crate::linalg::NodeBlock;
use crate::obs::{LedgerSnap, Phase, Recorder};
use crate::optim::refpoint::RefPoint;
use crate::sim::parallel::NodePool;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct InnerConfig {
    pub eta: f64,
    pub gamma: f64,
    pub k_steps: usize,
}

/// How the inner loop evaluates the per-node gradient oracle ∇r_i.
///
/// Oracles write into a caller-provided row (`f(i, d_i, out)`), so the
/// serial path is allocation-free end to end; the parallel path stages
/// per-node rows through the pool (those sends allocate — oracle latency
/// dominates there anyway).
pub enum GradFn<'f, S: Scalar = f32> {
    /// One shared mutable closure, evaluated node by node into the batch.
    Serial(&'f mut dyn FnMut(usize, &[S], &mut [S])),
    /// A shareable closure fanned out over a [`NodePool`]; results land in
    /// node order, so the maths is identical to `Serial`.
    Parallel(&'f (dyn Fn(usize, &[S], &mut [S]) + Sync), &'f NodePool),
}

impl<S: Scalar> GradFn<'_, S> {
    /// Evaluate the oracle only at mask-active nodes (rows of inactive
    /// nodes are left untouched — callers must not read them).  The masked
    /// path is always serial: a sampled round evaluates few nodes, so pool
    /// fan-out overhead would dominate, and skipping pool sends keeps the
    /// active nodes' evaluation order identical to `Serial`.
    fn eval_active(&mut self, d: &[Vec<S>], out: &mut NodeBlock<S>, mask: Option<&[bool]>) {
        let Some(mask) = mask else {
            return self.eval_all(d, out);
        };
        debug_assert_eq!(d.len(), out.nrows());
        match self {
            GradFn::Serial(f) => {
                for (i, di) in d.iter().enumerate() {
                    if mask[i] {
                        f(i, di, out.row_mut(i));
                    }
                }
            }
            GradFn::Parallel(f, _) => {
                for (i, di) in d.iter().enumerate() {
                    if mask[i] {
                        f(i, di, out.row_mut(i));
                    }
                }
            }
        }
    }

    /// Evaluate the oracle at every node's current iterate, into `out`.
    fn eval_all(&mut self, d: &[Vec<S>], out: &mut NodeBlock<S>) {
        debug_assert_eq!(d.len(), out.nrows());
        match self {
            GradFn::Serial(f) => {
                for (i, di) in d.iter().enumerate() {
                    f(i, di, out.row_mut(i));
                }
            }
            GradFn::Parallel(f, pool) => {
                // Copy the shared-closure reference out of the &mut match
                // binding so the spawned closure captures a plain
                // `&(dyn Fn + Sync)`.
                let f: &(dyn Fn(usize, &[S], &mut [S]) + Sync) = *f;
                let dim = out.dim();
                let rows = pool.map(d.len(), |i| {
                    let mut row = vec![S::ZERO; dim];
                    f(i, &d[i], &mut row);
                    row
                });
                for (i, row) in rows.iter().enumerate() {
                    out.row_mut(i).copy_from_slice(row);
                }
            }
        }
    }
}

/// Per-variable persistent inner-loop state across outer rounds, plus all
/// steady-state scratch the hot loop reuses.
pub struct InnerState<S: Scalar = f32> {
    /// Model reference points (d̂, (d̂)_w) per node.
    pub d_ref: Vec<RefPoint<S>>,
    /// Tracker values s_i per node (contiguous m×d).
    pub s: NodeBlock<S>,
    /// Tracker reference points (ŝ, (ŝ)_w) per node.
    pub s_ref: Vec<RefPoint<S>>,
    /// Gradient folded into the tracker last (∇r_i^k), contiguous m×d.
    pub prev_grad: NodeBlock<S>,
    initialized: bool,
    /// Naive-variant error-feedback accumulators (e_i) for d and s.
    err_d: NodeBlock<S>,
    err_s: NodeBlock<S>,
    /// Transport graph epoch the reference points were built against.
    epoch: u64,
    /// Telemetry recorder; defaults to the no-op recorder (one branch per
    /// instrumentation point, no allocation).  Algorithms install a scoped
    /// handle ([`Recorder::scoped`]) so y- and z-sequence phases separate.
    pub obs: Recorder,
    /// Inner k-steps executed over this state's lifetime (stamps
    /// refpoint-reset events; telemetry only, no algorithmic role).
    steps: u64,
    // ---- reused per-step scratch (never reallocated in steady state) ----
    /// One compressed-message slot per node (payload buffers reused).
    msgs: Vec<Compressed<S>>,
    /// Wire sizes of the current message set.
    bytes: Vec<usize>,
    /// Delivered-sender lists from the last exchange.
    delivered: Vec<Vec<usize>>,
    /// Dense residual / error-feedback carry scratch (one row).
    resid: Vec<S>,
    /// Fresh gradient batch ∇r^{k+1} (swapped into `prev_grad`).
    g_new: NodeBlock<S>,
    /// Naive variant only: densified own messages Q_i, contiguous m×d.
    /// Empty until the first `run_inner_naive_with` call sizes it, so the
    /// reference-point path never pays for it.
    own: NodeBlock<S>,
    /// Sampling-mask snapshot buffer (copied from the transport at the top
    /// of each inner call so the mask cannot shift mid-call; reused, so
    /// the masked path stays allocation-free in steady state too).
    mask_buf: Vec<bool>,
}

impl<S: Scalar> InnerState<S> {
    pub fn new<T: Transport>(net: &T, dim: usize) -> InnerState<S> {
        let m = net.m();
        let mk_refs = || {
            (0..m)
                .map(|i| RefPoint::new(dim, 1.0 - net.weight(i, i)))
                .collect::<Vec<_>>()
        };
        InnerState {
            d_ref: mk_refs(),
            s: NodeBlock::zeros(m, dim),
            s_ref: mk_refs(),
            prev_grad: NodeBlock::zeros(m, dim),
            initialized: false,
            err_d: NodeBlock::zeros(m, dim),
            err_s: NodeBlock::zeros(m, dim),
            epoch: net.graph_epoch(),
            obs: Recorder::noop(),
            steps: 0,
            msgs: (0..m).map(|_| Compressed::empty()).collect(),
            bytes: Vec::with_capacity(m),
            delivered: vec![Vec::new(); m],
            resid: Vec::with_capacity(dim),
            g_new: NodeBlock::zeros(m, dim),
            own: NodeBlock::default(),
            mask_buf: Vec::new(),
        }
    }

    /// Reference points are keyed to a fixed mixing matrix: the
    /// neighbour-weight sums and the `(d̂)_w` accumulators are meaningless
    /// once the graph changes.  When the transport reports a new graph
    /// epoch (time-varying topologies), perform the resync a real
    /// deployment would: every node simultaneously resets its reference
    /// points against the new weights — the next residuals are then full
    /// snapshots `Q(d − 0)` and the invariant `(d̂)_w = Σ w_ij d̂_j` holds
    /// again by construction.  Local tracker values, gradients and
    /// error-feedback accumulators carry over.  No-op on static graphs.
    fn sync_topology<T: Transport>(&mut self, net: &T) {
        if net.graph_epoch() == self.epoch {
            return;
        }
        self.resync(net);
    }

    /// Unconditionally rebuild the reference points against the
    /// transport's current epoch/weights (in place, allocation-free).
    fn resync<T: Transport>(&mut self, net: &T) {
        self.epoch = net.graph_epoch();
        for i in 0..self.d_ref.len() {
            let sw = 1.0 - net.weight(i, i);
            self.d_ref[i].reset(sw);
            self.s_ref[i].reset(sw);
        }
        self.obs.reset(self.steps, self.epoch);
    }

    /// Tracker bootstrap on the very first call: s_i⁰ = ∇r_i(d_i⁰).  On
    /// warm starts the tracker carries over and self-corrects through the
    /// gradient-difference term.  Returns oracle calls made (0 or m).
    fn bootstrap(&mut self, d: &[Vec<S>], grad: &mut GradFn<S>) -> u64 {
        if self.initialized {
            return 0;
        }
        grad.eval_all(d, &mut self.g_new);
        self.prev_grad.copy_from(&self.g_new);
        self.s.copy_from(&self.g_new);
        self.initialized = true;
        d.len() as u64
    }
}

/// Snapshot the graph epoch, run the borrowing exchange, and report
/// whether the delivered messages still belong to that epoch (safe to fold
/// with current weights).  A schedule tick during the exchange makes the
/// in-flight messages stale: the caller must drop them and resync.
fn exchange_same_epoch<T: Transport>(
    net: &mut T,
    bytes: &[usize],
    delivered: &mut Vec<Vec<usize>>,
) -> bool {
    let epoch_before = net.graph_epoch();
    net.exchange_indices(bytes, delivered);
    net.graph_epoch() == epoch_before
}

/// Defense against misbehaving transports: the [`Transport`] contract says
/// every delivered-sender list is strictly ascending (each neighbour's
/// message at most once).  A duplicate would fold the same residual into a
/// reference-point accumulator twice — silent, unbounded divergence that no
/// downstream check catches — so refuse loudly instead.
fn check_delivered_contract(receiver: usize, delivered: &[usize]) {
    for w in delivered.windows(2) {
        assert!(
            w[0] < w[1],
            "transport contract violated: node {receiver} was handed senders \
             {delivered:?} (duplicated or out-of-order delivery); folding \
             would silently corrupt the reference points"
        );
    }
}

/// Run K steps of Algorithm 2 over all nodes with a plain serial oracle
/// returning freshly allocated gradients (convenience wrapper; the
/// returned vectors are copied into the reusable batch).
///
/// `d` is the per-node variable (y or z), updated in place.  `grad(i, d_i)`
/// is the local first-order oracle ∇r_i.  Communication (two compressed
/// messages per node per step) is paid through `net`.  Returns the number
/// of oracle calls made.
pub fn run_inner<S: Scalar, T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor<S>,
    rng: &mut Rng,
    state: &mut InnerState<S>,
    d: &mut [Vec<S>],
    mut grad: impl FnMut(usize, &[S]) -> Vec<S>,
) -> u64 {
    let mut g = |i: usize, di: &[S], out: &mut [S]| out.copy_from_slice(&grad(i, di));
    run_inner_with(cfg, net, compressor, rng, state, d, GradFn::Serial(&mut g))
}

/// [`run_inner`] with an explicit (possibly parallel) in-place oracle.
pub fn run_inner_with<S: Scalar, T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor<S>,
    rng: &mut Rng,
    state: &mut InnerState<S>,
    d: &mut [Vec<S>],
    mut grad: GradFn<S>,
) -> u64 {
    let m = net.m();
    debug_assert_eq!(d.len(), m);
    // Snapshot the sampling mask for the whole call (the buffer is reused,
    // so this stays allocation-free in steady state).  Semantics: inactive
    // nodes pay no oracle calls and transmit nothing, but they still FOLD
    // delivered neighbour residuals into their reference points — that
    // passive fold is what keeps `(d̂)_w = Σ w_ij d̂_j` true at every node
    // while only a subset participates.  Bootstrap intentionally ignores
    // the mask: `s_i⁰ = ∇r_i(d_i⁰)` must hold at every node once.
    let mut mask_store = std::mem::take(&mut state.mask_buf);
    mask_store.clear();
    let masked = match net.active() {
        Some(a) => {
            debug_assert_eq!(a.len(), m);
            mask_store.extend_from_slice(a);
            true
        }
        None => false,
    };
    let active_nodes = if masked {
        mask_store.iter().filter(|&&a| a).count() as u64
    } else {
        m as u64
    };
    let mut calls = state.bootstrap(d, &mut grad);

    let eta = S::from_f64(cfg.eta);
    let gamma = S::from_f64(cfg.gamma);

    for _k in 0..cfg.k_steps {
        // A topology switch between steps invalidates the reference
        // points; resync first.  (Mid-exchange switches are handled at
        // each exchange below.)
        state.sync_topology(net);

        // -- 1. model update: d ← d + γ((d̂)_w − sw·d̂) − η s  --------------
        //       (sampled-out nodes freeze: no mix, no descent)
        let t = state.obs.clock();
        for (i, di) in d.iter_mut().enumerate() {
            if masked && !mask_store[i] {
                continue;
            }
            state.d_ref[i].add_mix_term(gamma, di);
            kernels::descent(eta, state.s.row(i), di);
        }
        state.obs.phase(Phase::Mix, 0, t);
        // -- 2. transmit Q(d_new − d̂); update d̂, then fold each DELIVERED
        //       same-epoch neighbour message into (d̂)_w  -------------------
        //       Inactive nodes send nothing (their d̂ stays put, so their
        //       stale `msgs` slot is never read: transports only deliver
        //       active senders), but they DO fold incoming messages below.
        let t = state.obs.clock();
        for (i, di) in d.iter().enumerate() {
            if masked && !mask_store[i] {
                continue;
            }
            state.d_ref[i].residual_into(di, &mut state.resid);
            compressor.compress_into(&state.resid, &mut state.msgs[i], rng);
        }
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            state.d_ref[i].apply_own(&state.msgs[i]);
        }
        state.bytes.clear();
        if masked {
            state.bytes.extend(
                state
                    .msgs
                    .iter()
                    .enumerate()
                    .map(|(i, q)| if mask_store[i] { q.wire_bytes() } else { 0 }),
            );
        } else {
            state.bytes.extend(state.msgs.iter().map(Compressed::wire_bytes));
        }
        state.obs.phase(Phase::Compress, 0, t);
        state.obs.encoded(&state.msgs);
        let snap = LedgerSnap::of(net.ledger());
        let t = state.obs.clock();
        if exchange_same_epoch(net, &state.bytes, &mut state.delivered) {
            for i in 0..m {
                check_delivered_contract(i, &state.delivered[i]);
                for &j in &state.delivered[i] {
                    let wij = net.weight(i, j);
                    state.d_ref[i].apply_neighbor(wij, &state.msgs[j]);
                }
            }
            if state.obs.enabled() {
                state
                    .obs
                    .decoded(state.delivered.iter().map(|s| s.len() as u64).sum());
            }
        } else {
            // The graph switched while these messages were in flight:
            // folding them with new-epoch weights would corrupt the
            // accumulators.  Drop the dead-epoch round and resync.
            state.resync(net);
        }
        state
            .obs
            .exchange(Phase::Exchange, snap, net.ledger(), &state.bytes, net.last_events(), t);

        // -- 3. tracker update: s ← s + γ((ŝ)_w − sw·ŝ) + ∇r^{new} − ∇r^{old}
        //       (active nodes only; an inactive node's s and ∇r stay put,
        //       exactly like a node that slept through the round)
        let t = state.obs.clock();
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            state.s_ref[i].add_mix_term(gamma, state.s.row_mut(i));
        }
        state.obs.phase(Phase::Tracker, 0, t);
        let t = state.obs.clock();
        grad.eval_active(d, &mut state.g_new, masked.then_some(mask_store.as_slice()));
        calls += active_nodes;
        state.obs.phase(Phase::Grad, active_nodes, t);
        let t = state.obs.clock();
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            kernels::add_diff(state.g_new.row(i), state.prev_grad.row(i), state.s.row_mut(i));
        }
        if masked {
            // Only active rows of `g_new` are fresh; a wholesale swap would
            // ping-pong stale gradients into inactive nodes' `prev_grad`.
            for i in 0..m {
                if mask_store[i] {
                    state.prev_grad.row_mut(i).copy_from_slice(state.g_new.row(i));
                }
            }
        } else {
            std::mem::swap(&mut state.prev_grad, &mut state.g_new);
        }
        state.obs.phase(Phase::Tracker, 0, t);

        // -- 4. transmit Q(s_new − ŝ); update ŝ and delivered (ŝ)_w  -------
        let t = state.obs.clock();
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            state.s_ref[i].residual_into(state.s.row(i), &mut state.resid);
            compressor.compress_into(&state.resid, &mut state.msgs[i], rng);
        }
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            state.s_ref[i].apply_own(&state.msgs[i]);
        }
        state.bytes.clear();
        if masked {
            state.bytes.extend(
                state
                    .msgs
                    .iter()
                    .enumerate()
                    .map(|(i, q)| if mask_store[i] { q.wire_bytes() } else { 0 }),
            );
        } else {
            state.bytes.extend(state.msgs.iter().map(Compressed::wire_bytes));
        }
        state.obs.phase(Phase::Compress, 0, t);
        state.obs.encoded(&state.msgs);
        let snap = LedgerSnap::of(net.ledger());
        let t = state.obs.clock();
        if exchange_same_epoch(net, &state.bytes, &mut state.delivered) {
            for i in 0..m {
                check_delivered_contract(i, &state.delivered[i]);
                for &j in &state.delivered[i] {
                    let wij = net.weight(i, j);
                    state.s_ref[i].apply_neighbor(wij, &state.msgs[j]);
                }
            }
            if state.obs.enabled() {
                state
                    .obs
                    .decoded(state.delivered.iter().map(|s| s.len() as u64).sum());
            }
        } else {
            state.resync(net);
        }
        state
            .obs
            .exchange(Phase::Exchange, snap, net.ledger(), &state.bytes, net.last_events(), t);
        state.steps += 1;
    }
    state.mask_buf = mask_store;
    calls
}

/// The C²DFB(nc) ablation with a serial allocating oracle: per step each
/// node transmits `Q(d_i + e_i)` (error-feedback compression of the raw
/// parameter), neighbours mix with the received compressed values.  Same
/// message count/sizes as [`run_inner`] but errors accumulate locally
/// instead of being implicitly shared — the paper's Fig. 3 shows this is
/// slower and less stable.  Returns the number of oracle calls made.
pub fn run_inner_naive<S: Scalar, T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor<S>,
    rng: &mut Rng,
    state: &mut InnerState<S>,
    d: &mut [Vec<S>],
    mut grad: impl FnMut(usize, &[S]) -> Vec<S>,
) -> u64 {
    let mut g = |i: usize, di: &[S], out: &mut [S]| out.copy_from_slice(&grad(i, di));
    run_inner_naive_with(cfg, net, compressor, rng, state, d, GradFn::Serial(&mut g))
}

/// [`run_inner_naive`] with an explicit (possibly parallel) in-place
/// oracle.
pub fn run_inner_naive_with<S: Scalar, T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor<S>,
    rng: &mut Rng,
    state: &mut InnerState<S>,
    d: &mut [Vec<S>],
    mut grad: GradFn<S>,
) -> u64 {
    let m = net.m();
    // Mask semantics for the naive variant are simpler than the refpoint
    // protocol's: there are no shared accumulators to keep consistent, so
    // an inactive node just sits the step out entirely — no send, no fold,
    // no descent, no oracle.  (Active receivers still mix the delivered
    // active senders' messages.)
    let mut mask_store = std::mem::take(&mut state.mask_buf);
    mask_store.clear();
    let masked = match net.active() {
        Some(a) => {
            debug_assert_eq!(a.len(), m);
            mask_store.extend_from_slice(a);
            true
        }
        None => false,
    };
    let active_nodes = if masked {
        mask_store.iter().filter(|&&a| a).count() as u64
    } else {
        m as u64
    };
    let mut calls = state.bootstrap(d, &mut grad);
    let eta = S::from_f64(cfg.eta);
    let gamma = S::from_f64(cfg.gamma);
    // Size the naive-only dense-message block on first use (no-op and
    // allocation-free afterwards; contents are fully overwritten below).
    state.own.reset(m, state.g_new.dim());

    for _k in 0..cfg.k_steps {
        // Compress d with error feedback: carry = d + e, e ← carry − Q(carry).
        let t = state.obs.clock();
        for (i, di) in d.iter().enumerate() {
            if masked && !mask_store[i] {
                continue;
            }
            state.resid.clear();
            state.resid.extend_from_slice(di);
            kernels::add_assign(&mut state.resid, state.err_d.row(i));
            compressor.compress_into(&state.resid, &mut state.msgs[i], rng);
            state.msgs[i].decompress_into(state.own.row_mut(i));
            kernels::sub(&state.resid, state.own.row(i), state.err_d.row_mut(i));
        }
        state.bytes.clear();
        if masked {
            state.bytes.extend(
                state
                    .msgs
                    .iter()
                    .enumerate()
                    .map(|(i, q)| if mask_store[i] { q.wire_bytes() } else { 0 }),
            );
        } else {
            state.bytes.extend(state.msgs.iter().map(Compressed::wire_bytes));
        }
        state.obs.phase(Phase::Compress, 0, t);
        state.obs.encoded(&state.msgs);
        // d_i ← d_i + γ Σ w_ij (Q_j − Q_i) − η s_i over DELIVERED messages
        // of the SAME graph epoch (a delivered q IS the sender's message —
        // its dense form is already in `own`).  If the graph switched
        // mid-exchange, the stale round is dropped, not folded with
        // new-epoch weights.
        let snap = LedgerSnap::of(net.ledger());
        let t = state.obs.clock();
        let fold = exchange_same_epoch(net, &state.bytes, &mut state.delivered);
        state
            .obs
            .exchange(Phase::Exchange, snap, net.ledger(), &state.bytes, net.last_events(), t);
        if fold && state.obs.enabled() {
            state
                .obs
                .decoded(state.delivered.iter().map(|s| s.len() as u64).sum());
        }
        let t = state.obs.clock();
        for (i, di) in d.iter_mut().enumerate() {
            if masked && !mask_store[i] {
                continue;
            }
            if fold {
                check_delivered_contract(i, &state.delivered[i]);
                for &sender in &state.delivered[i] {
                    let w = S::from_f64(gamma.to_f64() * net.weight(i, sender));
                    kernels::weighted_diff_add(w, state.own.row(sender), state.own.row(i), di);
                }
            }
            kernels::descent(eta, state.s.row(i), di);
        }
        state.obs.phase(Phase::Mix, 0, t);
        // Tracker: same naive scheme on s.
        let t = state.obs.clock();
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            state.resid.clear();
            state.resid.extend_from_slice(state.s.row(i));
            kernels::add_assign(&mut state.resid, state.err_s.row(i));
            compressor.compress_into(&state.resid, &mut state.msgs[i], rng);
            state.msgs[i].decompress_into(state.own.row_mut(i));
            kernels::sub(&state.resid, state.own.row(i), state.err_s.row_mut(i));
        }
        state.bytes.clear();
        if masked {
            state.bytes.extend(
                state
                    .msgs
                    .iter()
                    .enumerate()
                    .map(|(i, q)| if mask_store[i] { q.wire_bytes() } else { 0 }),
            );
        } else {
            state.bytes.extend(state.msgs.iter().map(Compressed::wire_bytes));
        }
        state.obs.phase(Phase::Compress, 0, t);
        state.obs.encoded(&state.msgs);
        let snap = LedgerSnap::of(net.ledger());
        let t = state.obs.clock();
        let fold = exchange_same_epoch(net, &state.bytes, &mut state.delivered);
        state
            .obs
            .exchange(Phase::Exchange, snap, net.ledger(), &state.bytes, net.last_events(), t);
        if fold && state.obs.enabled() {
            state
                .obs
                .decoded(state.delivered.iter().map(|s| s.len() as u64).sum());
        }
        let t = state.obs.clock();
        if fold {
            for i in 0..m {
                if masked && !mask_store[i] {
                    continue;
                }
                check_delivered_contract(i, &state.delivered[i]);
                for &sender in &state.delivered[i] {
                    let w = S::from_f64(gamma.to_f64() * net.weight(i, sender));
                    let (own, s) = (&state.own, &mut state.s);
                    kernels::weighted_diff_add(w, own.row(sender), own.row(i), s.row_mut(i));
                }
            }
        }
        state.obs.phase(Phase::Mix, 0, t);
        let t = state.obs.clock();
        grad.eval_active(d, &mut state.g_new, masked.then_some(mask_store.as_slice()));
        calls += active_nodes;
        state.obs.phase(Phase::Grad, active_nodes, t);
        let t = state.obs.clock();
        for i in 0..m {
            if masked && !mask_store[i] {
                continue;
            }
            kernels::add_diff(state.g_new.row(i), state.prev_grad.row(i), state.s.row_mut(i));
        }
        if masked {
            for i in 0..m {
                if mask_store[i] {
                    state.prev_grad.row_mut(i).copy_from_slice(state.g_new.row(i));
                }
            }
        } else {
            std::mem::swap(&mut state.prev_grad, &mut state.g_new);
        }
        state.obs.phase(Phase::Tracker, 0, t);
        state.steps += 1;
    }
    state.mask_buf = mask_store;
    calls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::compress::{Identity, TopK};
    use crate::linalg;
    use crate::sim::{NetConfig, NetMode, SimNetwork};
    use crate::topology::{Graph, Topology};

    /// Heterogeneous strongly-convex quadratics:
    /// r_i(d) = ½ aᵢ‖d − cᵢ‖² with global optimum d* = Σaᵢcᵢ / Σaᵢ.
    struct Quad {
        a: Vec<f32>,
        c: Vec<Vec<f32>>,
    }

    impl Quad {
        fn build(m: usize, dim: usize, seed: u64) -> Quad {
            let mut rng = Rng::new(seed);
            Quad {
                a: (0..m).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                c: (0..m)
                    .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect())
                    .collect(),
            }
        }

        fn grad(&self, i: usize, d: &[f32]) -> Vec<f32> {
            d.iter()
                .zip(&self.c[i])
                .map(|(x, c)| self.a[i] * (x - c))
                .collect()
        }

        fn optimum(&self, dim: usize) -> Vec<f32> {
            let asum: f32 = self.a.iter().sum();
            let mut out = vec![0.0f32; dim];
            for i in 0..self.a.len() {
                for k in 0..dim {
                    out[k] += self.a[i] * self.c[i][k] / asum;
                }
            }
            out
        }
    }

    fn run(
        compressor: &dyn Compressor,
        steps: usize,
        naive: bool,
    ) -> (f64, f64) {
        let m = 6;
        let dim = 8;
        let q = Quad::build(m, dim, 42);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(7);
        let cfg = InnerConfig { eta: 0.15, gamma: 0.6, k_steps: steps };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        let g = |i: usize, di: &[f32]| q.grad(i, di);
        if naive {
            run_inner_naive(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        } else {
            run_inner(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        }
        let opt = q.optimum(dim);
        let err: f64 = d
            .iter()
            .map(|di| {
                di.iter()
                    .zip(&opt)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>()
            })
            .sum();
        (err, linalg::consensus_err_sq(&d))
    }

    #[test]
    fn converges_uncompressed() {
        let (err, cons) = run(&Identity, 400, false);
        assert!(err < 1e-6, "optimality err {err}");
        assert!(cons < 1e-6, "consensus err {cons}");
    }

    #[test]
    fn converges_with_topk() {
        let (err, cons) = run(&TopK::new(0.25), 800, false);
        assert!(err < 1e-4, "optimality err {err}");
        assert!(cons < 1e-4, "consensus err {cons}");
    }

    /// The full protocol is dtype-generic: at f64 the same quadratic
    /// setup converges well past the f32 noise floor.
    #[test]
    fn converges_at_f64() {
        let m = 6;
        let dim = 8;
        let q32 = Quad::build(m, dim, 42);
        let a: Vec<f64> = q32.a.iter().map(|&x| x as f64).collect();
        let c: Vec<Vec<f64>> = q32
            .c
            .iter()
            .map(|r| r.iter().map(|&x| x as f64).collect())
            .collect();
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(7);
        let cfg = InnerConfig { eta: 0.15, gamma: 0.6, k_steps: 800 };
        let mut state = InnerState::<f64>::new(&net, dim);
        let mut d = vec![vec![0.0f64; dim]; m];
        let g = |i: usize, di: &[f64]| -> Vec<f64> {
            di.iter().zip(&c[i]).map(|(x, ci)| a[i] * (x - ci)).collect()
        };
        run_inner(&cfg, &mut net, &TopK::new(0.25), &mut rng, &mut state, &mut d, g);
        let asum: f64 = a.iter().sum();
        let mut opt = vec![0.0f64; dim];
        for i in 0..m {
            for k in 0..dim {
                opt[k] += a[i] * c[i][k] / asum;
            }
        }
        let err: f64 = d
            .iter()
            .map(|di| di.iter().zip(&opt).map(|(x, o)| (x - o).powi(2)).sum::<f64>())
            .sum();
        assert!(err < 1e-10, "f64 optimality err {err}");
    }

    /// Theorem 1 shape: error after 2K steps ≪ error after K steps
    /// (linear rate), measured on the compressed protocol.  Stops checking
    /// once the error hits the f32 noise floor.
    #[test]
    fn linear_rate_doubling_k() {
        let floor = 1e-9;
        let (e1, _) = run(&TopK::new(0.25), 25, false);
        let (e2, _) = run(&TopK::new(0.25), 50, false);
        let (e4, _) = run(&TopK::new(0.25), 100, false);
        println!("linear_rate: e25={e1:.3e} e50={e2:.3e} e100={e4:.3e}");
        if e2 > floor {
            assert!(e2 < e1 * 0.5, "{e2} !< {e1}/2");
        }
        if e4 > floor {
            assert!(e4 < e2 * 0.5, "{e4} !< {e2}/2");
        }
        assert!(e4 < 1e-5, "not converged after 100 steps: {e4}");
    }

    /// The naive variant still roughly works on easy quadratics but the
    /// reference-point protocol reaches a (weakly) better point for the
    /// same budget — and must never be catastrophically unstable here.
    #[test]
    fn refpoint_no_worse_than_naive() {
        let (e_ref, _) = run(&TopK::new(0.25), 300, false);
        let (e_nc, _) = run(&TopK::new(0.25), 300, true);
        assert!(e_ref.is_finite() && e_nc.is_finite());
        assert!(e_ref <= e_nc * 1.5, "ref {e_ref} vs naive {e_nc}");
    }

    /// Eq. 7: the node-average follows the uncompressed dynamics
    /// d̄ ← d̄ − η s̄ exactly, for any compressor.
    #[test]
    fn mean_follows_uncompressed_dynamics() {
        let m = 5;
        let dim = 6;
        let q = Quad::build(m, dim, 9);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(1);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i * k) as f32 * 0.1).collect())
            .collect();
        // Bootstrap tracker (first run_inner call does it internally, but we
        // need s̄ BEFORE the step to predict the mean).
        for i in 0..m {
            let g = q.grad(i, &d[i]);
            state.prev_grad.row_mut(i).copy_from_slice(&g);
            state.s.row_mut(i).copy_from_slice(&g);
        }
        state.initialized = true;

        for _step in 0..5 {
            let mean_before = linalg::mean_rows(&d);
            let s_mean = state.s.mean_row();
            let g = |i: usize, di: &[f32]| q.grad(i, di);
            run_inner(&cfg, &mut net, &TopK::new(0.3), &mut rng, &mut state, &mut d, g);
            let mean_after = linalg::mean_rows(&d);
            for k in 0..dim {
                let predicted = mean_before[k] - cfg.eta as f32 * s_mean[k];
                assert!(
                    (mean_after[k] - predicted).abs() < 1e-4,
                    "coord {k}: {} vs {}",
                    mean_after[k],
                    predicted
                );
            }
        }
    }

    #[test]
    fn communication_is_compressed() {
        let m = 6;
        let dim = 1000;
        let q = Quad::build(m, dim, 3);
        let mut rng = Rng::new(2);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 5 };

        let mut net_dense = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_dense, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_dense, &Identity, &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let dense_bytes = net_dense.ledger.total_bytes;

        let mut net_topk = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_topk, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_topk, &TopK::new(0.1), &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let topk_bytes = net_topk.ledger.total_bytes;
        assert!(
            (topk_bytes as f64) < dense_bytes as f64 * 0.3,
            "{topk_bytes} vs {dense_bytes}"
        );
    }

    /// Oracle-call accounting: bootstrap m, then m per step.
    #[test]
    fn returns_oracle_call_count() {
        let m = 6;
        let dim = 4;
        let q = Quad::build(m, dim, 5);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(4);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 3 };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        let n1 = run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d, |i, x| {
            q.grad(i, x)
        });
        assert_eq!(n1, (m + 3 * m) as u64); // bootstrap + per-step
        let n2 = run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d, |i, x| {
            q.grad(i, x)
        });
        assert_eq!(n2, (3 * m) as u64); // warm start: no bootstrap
    }

    /// A parallel oracle over a NodePool gives bit-identical trajectories
    /// to the serial closure, at any thread count.
    #[test]
    fn parallel_oracle_matches_serial_exactly() {
        let m = 6;
        let dim = 16;
        let q = Quad::build(m, dim, 13);
        let run_with_pool = |threads: usize| {
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            let mut rng = Rng::new(8);
            let cfg = InnerConfig { eta: 0.12, gamma: 0.6, k_steps: 40 };
            let mut state = InnerState::new(&net, dim);
            let mut d = vec![vec![0.0f32; dim]; m];
            let g = |i: usize, di: &[f32], out: &mut [f32]| {
                out.copy_from_slice(&q.grad(i, di))
            };
            let pool = NodePool::new(threads);
            let calls = if threads == 1 {
                let mut gs = g;
                run_inner_with(
                    &cfg,
                    &mut net,
                    &TopK::new(0.3),
                    &mut rng,
                    &mut state,
                    &mut d,
                    GradFn::Serial(&mut gs),
                )
            } else {
                run_inner_with(
                    &cfg,
                    &mut net,
                    &TopK::new(0.3),
                    &mut rng,
                    &mut state,
                    &mut d,
                    GradFn::Parallel(&g, &pool),
                )
            };
            (calls, d)
        };
        let (c1, d1) = run_with_pool(1);
        for threads in [2, 4] {
            let (c, d) = run_with_pool(threads);
            assert_eq!(c, c1);
            assert_eq!(d, d1, "trajectory diverged at {threads} threads");
        }
    }

    /// The refpoint invariant `(d̂)_w = Σ_j w_ij d̂_j` against the CURRENT
    /// mixing matrix, for both the model and tracker reference points.
    fn assert_refpoint_invariant<T: Transport>(net: &T, state: &InnerState, tol: f64) {
        let m = net.m();
        for refs in [&state.d_ref, &state.s_ref] {
            for i in 0..m {
                for k in 0..refs[i].hat.len() {
                    // Non-neighbours have weight exactly 0.0, so summing
                    // over all j≠i equals the neighbour-only sum.
                    let direct: f64 = (0..m)
                        .filter(|&j| j != i)
                        .map(|j| net.weight(i, j) * refs[j].hat[k] as f64)
                        .sum();
                    assert!(
                        (refs[i].hat_w[k] as f64 - direct).abs() < tol,
                        "invariant broken at node {i} coord {k}: {} vs {direct}",
                        refs[i].hat_w[k]
                    );
                }
            }
        }
    }

    /// Regression (mid-step topology-epoch weight mismatch): a graph-
    /// schedule tick DURING an exchange must not fold the old-graph
    /// messages with new-epoch weights.  The schedule below switches the
    /// graph at gossip round 1 — i.e. during the SECOND (tracker) exchange
    /// of the first inner step, mid-step.  After the step the reference
    /// points must satisfy the accumulator invariant under the NEW mixing
    /// matrix; pre-fix, the stale fold left `(ŝ)_w` inconsistent until the
    /// next step's resync, and anything computed from it in between was
    /// silently wrong.
    #[test]
    fn mid_step_topology_tick_keeps_refpoints_consistent() {
        let m = 6;
        let dim = 5;
        let q = Quad::build(m, dim, 19);
        let cfg_net = NetConfig {
            mode: NetMode::Event,
            topology_schedule: vec![(1, Topology::Complete)],
            ..NetConfig::default()
        };
        let build =
            || SimNetwork::new(Graph::build(Topology::Ring, m), cfg_net.clone(), 5).unwrap();

        // One step: the tick lands between this step's two exchanges.
        let mut net = build();
        let mut rng = Rng::new(3);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i + k) as f32 * 0.3).collect())
            .collect();
        let g = |i: usize, di: &[f32]| q.grad(i, di);
        run_inner(&cfg, &mut net, &TopK::new(0.5), &mut rng, &mut state, &mut d, g);
        assert_eq!(net.graph_epoch(), 1, "schedule must have ticked mid-step");
        assert_refpoint_invariant(&net, &state, 1e-5);

        // Several more steps across the tick: still consistent and finite.
        let mut net = build();
        let mut rng = Rng::new(3);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 6 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i + k) as f32 * 0.3).collect())
            .collect();
        run_inner(&cfg, &mut net, &TopK::new(0.5), &mut rng, &mut state, &mut d, g);
        assert_refpoint_invariant(&net, &state, 1e-4);
        assert!(d.iter().flatten().all(|x| x.is_finite()));

        // The naive variant takes the same guarded path: deterministic
        // and finite across mid-step ticks.
        let run_naive = || {
            let mut net = build();
            let mut rng = Rng::new(3);
            let mut state = InnerState::new(&net, dim);
            let mut d: Vec<Vec<f32>> = (0..m)
                .map(|i| (0..dim).map(|k| (i + k) as f32 * 0.3).collect())
                .collect();
            run_inner_naive(&cfg, &mut net, &TopK::new(0.5), &mut rng, &mut state, &mut d, g);
            d
        };
        let d1 = run_naive();
        let d2 = run_naive();
        assert_eq!(d1, d2);
        assert!(d1.iter().flatten().all(|x| x.is_finite()));
    }

    /// Node sampling: inactive nodes freeze (model rows untouched, no
    /// oracle calls charged for them) while the refpoint invariant
    /// `(d̂)_w = Σ w_ij d̂_j` keeps holding at EVERY node — the passive
    /// fold at inactive receivers is what makes that true.
    #[test]
    fn sampling_mask_freezes_inactive_and_keeps_invariant() {
        use std::sync::Arc;
        let m = 6;
        let dim = 5;
        let q = Quad::build(m, dim, 23);
        let mask: Vec<bool> = vec![true, false, true, true, false, true];
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        net.set_active(Some(Arc::new(mask.clone())));
        let mut rng = Rng::new(11);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 7 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i + 2 * k) as f32 * 0.2).collect())
            .collect();
        let d0 = d.clone();
        let g = |i: usize, di: &[f32]| q.grad(i, di);
        let calls =
            run_inner(&cfg, &mut net, &TopK::new(0.5), &mut rng, &mut state, &mut d, g);
        // Bootstrap touches all m once; each step only the 4 active nodes.
        assert_eq!(calls, (m + 7 * 4) as u64);
        for i in 0..m {
            if mask[i] {
                assert_ne!(d[i], d0[i], "active node {i} should have moved");
            } else {
                assert_eq!(d[i], d0[i], "inactive node {i} must be frozen");
            }
        }
        assert_refpoint_invariant(&net, &state, 1e-5);
        assert!(d.iter().flatten().all(|x| x.is_finite()));

        // Naive variant under the same mask: frozen inactive rows, finite,
        // deterministic.
        let run_nc = || {
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            net.set_active(Some(Arc::new(mask.clone())));
            let mut rng = Rng::new(11);
            let mut state = InnerState::new(&net, dim);
            let mut d = d0.clone();
            run_inner_naive(&cfg, &mut net, &TopK::new(0.5), &mut rng, &mut state, &mut d, g);
            d
        };
        let n1 = run_nc();
        let n2 = run_nc();
        assert_eq!(n1, n2);
        for i in 0..m {
            if !mask[i] {
                assert_eq!(n1[i], d0[i], "naive inactive node {i} must be frozen");
            }
        }
        assert!(n1.iter().flatten().all(|x| x.is_finite()));
    }

    /// An all-true mask must be bit-identical to no mask at all: the
    /// masked code path may not perturb the unsampled trajectory.
    #[test]
    fn all_active_mask_is_bitwise_identical_to_unmasked() {
        use std::sync::Arc;
        let m = 5;
        let dim = 6;
        let q = Quad::build(m, dim, 31);
        let traj = |mask: Option<Arc<Vec<bool>>>, naive: bool| {
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            net.set_active(mask);
            let mut rng = Rng::new(6);
            let cfg = InnerConfig { eta: 0.12, gamma: 0.55, k_steps: 9 };
            let mut state = InnerState::new(&net, dim);
            let mut d = vec![vec![0.25f32; dim]; m];
            let g = |i: usize, di: &[f32]| q.grad(i, di);
            let calls = if naive {
                run_inner_naive(&cfg, &mut net, &TopK::new(0.4), &mut rng, &mut state, &mut d, g)
            } else {
                run_inner(&cfg, &mut net, &TopK::new(0.4), &mut rng, &mut state, &mut d, g)
            };
            (calls, d, net.ledger.total_bytes)
        };
        for naive in [false, true] {
            let all = Some(Arc::new(vec![true; m]));
            assert_eq!(traj(None, naive), traj(all, naive), "naive={naive}");
        }
    }

    /// Duplicated delivery must fail loudly, never fold twice: a transport
    /// that hands the same sender to a receiver twice in one exchange is
    /// rejected before any accumulator is touched.
    #[test]
    #[should_panic(expected = "transport contract violated")]
    fn duplicate_delivery_fails_loudly() {
        use crate::collective::Inbox;
        use crate::metrics::CommLedger;
        struct Duplicating(Network);
        impl Transport for Duplicating {
            fn m(&self) -> usize {
                self.0.m()
            }
            fn weight(&self, i: usize, j: usize) -> f64 {
                Transport::weight(&self.0, i, j)
            }
            fn ledger(&self) -> &CommLedger {
                &self.0.ledger
            }
            fn exchange<S: Scalar>(&mut self, msgs: Vec<Compressed<S>>) -> Inbox<Compressed<S>> {
                self.0.exchange(msgs)
            }
            fn exchange_dense<S: Scalar>(&mut self, vecs: &[Vec<S>]) -> Inbox<Vec<S>> {
                self.0.exchange_dense(vecs)
            }
            fn exchange_indices(&mut self, bytes: &[usize], delivered: &mut Vec<Vec<usize>>) {
                self.0.exchange_indices(bytes, delivered);
                if let Some(&first) = delivered[0].first() {
                    delivered[0].insert(0, first); // duplicate node 0's first sender
                }
            }
        }
        let m = 4;
        let dim = 3;
        let q = Quad::build(m, dim, 2);
        let mut net = Duplicating(Network::new(Graph::build(Topology::Ring, m)));
        let mut rng = Rng::new(1);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.5f32; dim]; m];
        run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d, |i, x| {
            q.grad(i, x)
        });
    }
}
