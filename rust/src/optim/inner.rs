//! The inner loop `IN` (Algorithm 2): K steps of compressed, gradient-
//! tracked decentralized gradient descent on a strongly-convex objective.
//!
//! Two variants:
//! * [`run_inner`] — the paper's reference-point protocol (compressed
//!   residuals for both the model and the tracker, implicit error
//!   compensation, Eq. 6–7).
//! * [`run_inner_naive`] — the C²DFB(nc) ablation: compress the parameters
//!   directly with local error feedback (classic error accumulation), no
//!   reference points.
//!
//! Both are generic over [`Transport`] and consume what the transport
//! *actually delivered*: on the synchronous engine that is every
//! neighbour's message (identical to the original lockstep formulation);
//! on the event engine, lost messages simply never reach the reference
//! points — the exact failure mode a real deployment would see.
//!
//! Gradient oracles go through [`GradFn`]: a serial closure, or a
//! `Sync` closure plus a [`NodePool`] to evaluate nodes concurrently.
//! Each step's oracle batch happens at a point where the evaluated
//! state is frozen, so parallel evaluation is bit-identical to serial.
//!
//! Inner state persists across outer rounds: Algorithm 1 passes
//! `(d̂_i^K)^t, (s_i^K)^t, (ŝ_i^K)^t` back into the next round's `IN` call
//! (warm start), which `InnerState` models.

use crate::collective::Transport;
use crate::compress::Compressor;
use crate::optim::refpoint::RefPoint;
use crate::sim::parallel::NodePool;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct InnerConfig {
    pub eta: f64,
    pub gamma: f64,
    pub k_steps: usize,
}

/// How the inner loop evaluates the per-node gradient oracle ∇r_i.
pub enum GradFn<'f> {
    /// One shared mutable closure, evaluated node by node.
    Serial(&'f mut dyn FnMut(usize, &[f32]) -> Vec<f32>),
    /// A shareable closure fanned out over a [`NodePool`]; results come
    /// back in node order, so the maths is identical to `Serial`.
    Parallel(&'f (dyn Fn(usize, &[f32]) -> Vec<f32> + Sync), &'f NodePool),
}

impl GradFn<'_> {
    /// Evaluate the oracle at every node's current iterate.
    fn eval_all(&mut self, d: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match self {
            GradFn::Serial(f) => d.iter().enumerate().map(|(i, di)| f(i, di)).collect(),
            GradFn::Parallel(f, pool) => {
                // Copy the shared-closure reference out of the &mut match
                // binding so the spawned closure captures a plain
                // `&(dyn Fn + Sync)`.
                let f: &(dyn Fn(usize, &[f32]) -> Vec<f32> + Sync) = *f;
                pool.map(d.len(), |i| f(i, &d[i]))
            }
        }
    }
}

/// Per-variable persistent inner-loop state across outer rounds.
pub struct InnerState {
    /// Model reference points (d̂, (d̂)_w) per node.
    pub d_ref: Vec<RefPoint>,
    /// Tracker values s_i per node.
    pub s: Vec<Vec<f32>>,
    /// Tracker reference points (ŝ, (ŝ)_w) per node.
    pub s_ref: Vec<RefPoint>,
    /// Gradient folded into the tracker last (∇r_i^k).
    pub prev_grad: Vec<Vec<f32>>,
    initialized: bool,
    /// Naive-variant error-feedback accumulators (e_i) for d and s.
    err_d: Vec<Vec<f32>>,
    err_s: Vec<Vec<f32>>,
    /// Transport graph epoch the reference points were built against.
    epoch: u64,
}

impl InnerState {
    pub fn new<T: Transport>(net: &T, dim: usize) -> InnerState {
        let m = net.m();
        let mk_refs = || {
            (0..m)
                .map(|i| RefPoint::new(dim, 1.0 - net.mixing().weight(i, i)))
                .collect::<Vec<_>>()
        };
        InnerState {
            d_ref: mk_refs(),
            s: vec![vec![0.0; dim]; m],
            s_ref: mk_refs(),
            prev_grad: vec![vec![0.0; dim]; m],
            initialized: false,
            err_d: vec![vec![0.0; dim]; m],
            err_s: vec![vec![0.0; dim]; m],
            epoch: net.graph_epoch(),
        }
    }

    /// Reference points are keyed to a fixed mixing matrix: the
    /// neighbour-weight sums and the `(d̂)_w` accumulators are meaningless
    /// once the graph changes.  When the transport reports a new graph
    /// epoch (time-varying topologies), perform the resync a real
    /// deployment would: every node simultaneously resets its reference
    /// points against the new weights — the next residuals are then full
    /// snapshots `Q(d − 0)` and the invariant `(d̂)_w = Σ w_ij d̂_j` holds
    /// again by construction.  Local tracker values, gradients and
    /// error-feedback accumulators carry over.  No-op on static graphs.
    fn sync_topology<T: Transport>(&mut self, net: &T) {
        let epoch = net.graph_epoch();
        if epoch == self.epoch {
            return;
        }
        self.epoch = epoch;
        let dim = self.d_ref.first().map_or(0, |r| r.hat.len());
        for i in 0..self.d_ref.len() {
            let sw = 1.0 - net.mixing().weight(i, i);
            self.d_ref[i] = RefPoint::new(dim, sw);
            self.s_ref[i] = RefPoint::new(dim, sw);
        }
    }

    /// Tracker bootstrap on the very first call: s_i⁰ = ∇r_i(d_i⁰).  On
    /// warm starts the tracker carries over and self-corrects through the
    /// gradient-difference term.  Returns oracle calls made (0 or m).
    fn bootstrap(&mut self, d: &[Vec<f32>], grad: &mut GradFn) -> u64 {
        if self.initialized {
            return 0;
        }
        let g = grad.eval_all(d);
        self.prev_grad = g.clone();
        self.s = g;
        self.initialized = true;
        d.len() as u64
    }
}

/// Run K steps of Algorithm 2 over all nodes with a plain serial oracle.
///
/// `d` is the per-node variable (y or z), updated in place.  `grad(i, d_i)`
/// is the local first-order oracle ∇r_i.  Communication (two compressed
/// messages per node per step) is paid through `net`.  Returns the number
/// of oracle calls made.
pub fn run_inner<T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: impl FnMut(usize, &[f32]) -> Vec<f32>,
) -> u64 {
    run_inner_with(cfg, net, compressor, rng, state, d, GradFn::Serial(&mut grad))
}

/// [`run_inner`] with an explicit (possibly parallel) oracle.
pub fn run_inner_with<T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: GradFn,
) -> u64 {
    let m = net.m();
    debug_assert_eq!(d.len(), m);
    let mut calls = state.bootstrap(d, &mut grad);

    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;

    for _k in 0..cfg.k_steps {
        // A topology switch (possibly mid-IN-call: schedules tick per
        // gossip round) invalidates the reference points; resync first.
        state.sync_topology(net);

        // -- 1. model update: d ← d + γ((d̂)_w − sw·d̂) − η s  --------------
        for i in 0..m {
            state.d_ref[i].add_mix_term(gamma, &mut d[i]);
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        // -- 2. transmit Q(d_new − d̂); update d̂, then fold each DELIVERED
        //       neighbour message into (d̂)_w  ------------------------------
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.d_ref[i].residual(&d[i]), rng))
            .collect();
        for i in 0..m {
            state.d_ref[i].apply_own(&msgs[i]);
        }
        let inbox = net.exchange(msgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (j, q) in arrived {
                let wij = net.mixing().weight(i, j);
                state.d_ref[i].apply_neighbor(wij, q.as_ref());
            }
        }

        // -- 3. tracker update: s ← s + γ((ŝ)_w − sw·ŝ) + ∇r^{new} − ∇r^{old}
        for i in 0..m {
            state.s_ref[i].add_mix_term(gamma, &mut state.s[i]);
        }
        let g_new = grad.eval_all(d);
        calls += m as u64;
        for i in 0..m {
            for ((sk, gn), go) in state.s[i]
                .iter_mut()
                .zip(&g_new[i])
                .zip(&state.prev_grad[i])
            {
                *sk += gn - go;
            }
        }
        state.prev_grad = g_new;

        // -- 4. transmit Q(s_new − ŝ); update ŝ and delivered (ŝ)_w  -------
        let msgs: Vec<_> = (0..m)
            .map(|i| compressor.compress(&state.s_ref[i].residual(&state.s[i]), rng))
            .collect();
        for i in 0..m {
            state.s_ref[i].apply_own(&msgs[i]);
        }
        let inbox = net.exchange(msgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (j, q) in arrived {
                let wij = net.mixing().weight(i, j);
                state.s_ref[i].apply_neighbor(wij, q.as_ref());
            }
        }
    }
    calls
}

/// The C²DFB(nc) ablation with a serial oracle: per step each node
/// transmits `Q(d_i + e_i)` (error-feedback compression of the raw
/// parameter), neighbours mix with the received compressed values.  Same
/// message count/sizes as [`run_inner`] but errors accumulate locally
/// instead of being implicitly shared — the paper's Fig. 3 shows this is
/// slower and less stable.  Returns the number of oracle calls made.
pub fn run_inner_naive<T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: impl FnMut(usize, &[f32]) -> Vec<f32>,
) -> u64 {
    run_inner_naive_with(cfg, net, compressor, rng, state, d, GradFn::Serial(&mut grad))
}

/// [`run_inner_naive`] with an explicit (possibly parallel) oracle.
pub fn run_inner_naive_with<T: Transport>(
    cfg: &InnerConfig,
    net: &mut T,
    compressor: &dyn Compressor,
    rng: &mut Rng,
    state: &mut InnerState,
    d: &mut [Vec<f32>],
    mut grad: GradFn,
) -> u64 {
    let m = net.m();
    let mut calls = state.bootstrap(d, &mut grad);
    let eta = cfg.eta as f32;
    let gamma = cfg.gamma as f32;

    for _k in 0..cfg.k_steps {
        // Compress d with error feedback.
        let mut msgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = d[i]
                .iter()
                .zip(&state.err_d[i])
                .map(|(a, e)| a + e)
                .collect();
            let q = compressor.compress(&carry, rng);
            // e ← (d + e) − Q(d + e)
            let dense = q.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_d[i] = carry;
            msgs.push(q);
        }
        let own: Vec<Vec<f32>> = msgs.iter().map(|q| q.to_dense()).collect();
        let inbox = net.exchange(msgs);
        // d_i ← d_i + γ Σ w_ij (Q_j − Q_i) − η s_i over DELIVERED messages
        // (a delivered q IS the sender's message — reuse its dense form).
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (sender, _q) in arrived {
                let w = (gamma as f64 * net.mixing().weight(i, sender)) as f32;
                let qd = &own[sender];
                for k in 0..d[i].len() {
                    d[i][k] += w * (qd[k] - own[i][k]);
                }
            }
            for (dk, sk) in d[i].iter_mut().zip(&state.s[i]) {
                *dk -= eta * sk;
            }
        }
        // Tracker: same naive scheme on s.
        let mut smsgs = Vec::with_capacity(m);
        for i in 0..m {
            let mut carry: Vec<f32> = state.s[i]
                .iter()
                .zip(&state.err_s[i])
                .map(|(a, e)| a + e)
                .collect();
            let q = compressor.compress(&carry, rng);
            let dense = q.to_dense();
            for (c, qv) in carry.iter_mut().zip(&dense) {
                *c -= qv;
            }
            state.err_s[i] = carry;
            smsgs.push(q);
        }
        let own: Vec<Vec<f32>> = smsgs.iter().map(|q| q.to_dense()).collect();
        let inbox = net.exchange(smsgs);
        for (i, arrived) in inbox.into_iter().enumerate() {
            for (sender, _q) in arrived {
                let w = (gamma as f64 * net.mixing().weight(i, sender)) as f32;
                let qd = &own[sender];
                for k in 0..state.s[i].len() {
                    state.s[i][k] += w * (qd[k] - own[i][k]);
                }
            }
        }
        let g_new = grad.eval_all(d);
        calls += m as u64;
        for i in 0..m {
            for ((sk, gn), go) in state.s[i]
                .iter_mut()
                .zip(&g_new[i])
                .zip(&state.prev_grad[i])
            {
                *sk += gn - go;
            }
        }
        state.prev_grad = g_new;
    }
    calls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::Network;
    use crate::compress::{Identity, TopK};
    use crate::linalg;
    use crate::topology::{Graph, Topology};

    /// Heterogeneous strongly-convex quadratics:
    /// r_i(d) = ½ aᵢ‖d − cᵢ‖² with global optimum d* = Σaᵢcᵢ / Σaᵢ.
    struct Quad {
        a: Vec<f32>,
        c: Vec<Vec<f32>>,
    }

    impl Quad {
        fn build(m: usize, dim: usize, seed: u64) -> Quad {
            let mut rng = Rng::new(seed);
            Quad {
                a: (0..m).map(|_| rng.uniform_in(0.5, 2.0)).collect(),
                c: (0..m)
                    .map(|_| (0..dim).map(|_| rng.normal_f32(0.0, 2.0)).collect())
                    .collect(),
            }
        }

        fn grad(&self, i: usize, d: &[f32]) -> Vec<f32> {
            d.iter()
                .zip(&self.c[i])
                .map(|(x, c)| self.a[i] * (x - c))
                .collect()
        }

        fn optimum(&self, dim: usize) -> Vec<f32> {
            let asum: f32 = self.a.iter().sum();
            let mut out = vec![0.0f32; dim];
            for i in 0..self.a.len() {
                for k in 0..dim {
                    out[k] += self.a[i] * self.c[i][k] / asum;
                }
            }
            out
        }
    }

    fn run(
        compressor: &dyn Compressor,
        steps: usize,
        naive: bool,
    ) -> (f64, f64) {
        let m = 6;
        let dim = 8;
        let q = Quad::build(m, dim, 42);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(7);
        let cfg = InnerConfig { eta: 0.15, gamma: 0.6, k_steps: steps };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        let g = |i: usize, di: &[f32]| q.grad(i, di);
        if naive {
            run_inner_naive(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        } else {
            run_inner(&cfg, &mut net, compressor, &mut rng, &mut state, &mut d, g);
        }
        let opt = q.optimum(dim);
        let err: f64 = d
            .iter()
            .map(|di| {
                di.iter()
                    .zip(&opt)
                    .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                    .sum::<f64>()
            })
            .sum();
        (err, linalg::consensus_err_sq(&d))
    }

    #[test]
    fn converges_uncompressed() {
        let (err, cons) = run(&Identity, 400, false);
        assert!(err < 1e-6, "optimality err {err}");
        assert!(cons < 1e-6, "consensus err {cons}");
    }

    #[test]
    fn converges_with_topk() {
        let (err, cons) = run(&TopK::new(0.25), 800, false);
        assert!(err < 1e-4, "optimality err {err}");
        assert!(cons < 1e-4, "consensus err {cons}");
    }

    /// Theorem 1 shape: error after 2K steps ≪ error after K steps
    /// (linear rate), measured on the compressed protocol.  Stops checking
    /// once the error hits the f32 noise floor.
    #[test]
    fn linear_rate_doubling_k() {
        let floor = 1e-9;
        let (e1, _) = run(&TopK::new(0.25), 25, false);
        let (e2, _) = run(&TopK::new(0.25), 50, false);
        let (e4, _) = run(&TopK::new(0.25), 100, false);
        println!("linear_rate: e25={e1:.3e} e50={e2:.3e} e100={e4:.3e}");
        if e2 > floor {
            assert!(e2 < e1 * 0.5, "{e2} !< {e1}/2");
        }
        if e4 > floor {
            assert!(e4 < e2 * 0.5, "{e4} !< {e2}/2");
        }
        assert!(e4 < 1e-5, "not converged after 100 steps: {e4}");
    }

    /// The naive variant still roughly works on easy quadratics but the
    /// reference-point protocol reaches a (weakly) better point for the
    /// same budget — and must never be catastrophically unstable here.
    #[test]
    fn refpoint_no_worse_than_naive() {
        let (e_ref, _) = run(&TopK::new(0.25), 300, false);
        let (e_nc, _) = run(&TopK::new(0.25), 300, true);
        assert!(e_ref.is_finite() && e_nc.is_finite());
        assert!(e_ref <= e_nc * 1.5, "ref {e_ref} vs naive {e_nc}");
    }

    /// Eq. 7: the node-average follows the uncompressed dynamics
    /// d̄ ← d̄ − η s̄ exactly, for any compressor.
    #[test]
    fn mean_follows_uncompressed_dynamics() {
        let m = 5;
        let dim = 6;
        let q = Quad::build(m, dim, 9);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(1);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
        let mut state = InnerState::new(&net, dim);
        let mut d: Vec<Vec<f32>> = (0..m)
            .map(|i| (0..dim).map(|k| (i * k) as f32 * 0.1).collect())
            .collect();
        // Bootstrap tracker (first run_inner call does it internally, but we
        // need s̄ BEFORE the step to predict the mean).
        for i in 0..m {
            let g = q.grad(i, &d[i]);
            state.prev_grad[i] = g.clone();
            state.s[i] = g;
        }
        state.initialized = true;

        for _step in 0..5 {
            let mean_before = linalg::mean_rows(&d);
            let s_mean = linalg::mean_rows(&state.s);
            let g = |i: usize, di: &[f32]| q.grad(i, di);
            run_inner(&cfg, &mut net, &TopK::new(0.3), &mut rng, &mut state, &mut d, g);
            let mean_after = linalg::mean_rows(&d);
            for k in 0..dim {
                let predicted = mean_before[k] - cfg.eta as f32 * s_mean[k];
                assert!(
                    (mean_after[k] - predicted).abs() < 1e-4,
                    "coord {k}: {} vs {}",
                    mean_after[k],
                    predicted
                );
            }
        }
    }

    #[test]
    fn communication_is_compressed() {
        let m = 6;
        let dim = 1000;
        let q = Quad::build(m, dim, 3);
        let mut rng = Rng::new(2);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 5 };

        let mut net_dense = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_dense, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_dense, &Identity, &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let dense_bytes = net_dense.ledger.total_bytes;

        let mut net_topk = Network::new(Graph::build(Topology::Ring, m));
        let mut st = InnerState::new(&net_topk, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        run_inner(&cfg, &mut net_topk, &TopK::new(0.1), &mut rng, &mut st, &mut d, |i, x| {
            q.grad(i, x)
        });
        let topk_bytes = net_topk.ledger.total_bytes;
        assert!(
            (topk_bytes as f64) < dense_bytes as f64 * 0.3,
            "{topk_bytes} vs {dense_bytes}"
        );
    }

    /// Oracle-call accounting: bootstrap m, then m per step.
    #[test]
    fn returns_oracle_call_count() {
        let m = 6;
        let dim = 4;
        let q = Quad::build(m, dim, 5);
        let mut net = Network::new(Graph::build(Topology::Ring, m));
        let mut rng = Rng::new(4);
        let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 3 };
        let mut state = InnerState::new(&net, dim);
        let mut d = vec![vec![0.0f32; dim]; m];
        let n1 = run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d, |i, x| {
            q.grad(i, x)
        });
        assert_eq!(n1, (m + 3 * m) as u64); // bootstrap + per-step
        let n2 = run_inner(&cfg, &mut net, &Identity, &mut rng, &mut state, &mut d, |i, x| {
            q.grad(i, x)
        });
        assert_eq!(n2, (3 * m) as u64); // warm start: no bootstrap
    }

    /// A parallel oracle over a NodePool gives bit-identical trajectories
    /// to the serial closure, at any thread count.
    #[test]
    fn parallel_oracle_matches_serial_exactly() {
        let m = 6;
        let dim = 16;
        let q = Quad::build(m, dim, 13);
        let run_with_pool = |threads: usize| {
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            let mut rng = Rng::new(8);
            let cfg = InnerConfig { eta: 0.12, gamma: 0.6, k_steps: 40 };
            let mut state = InnerState::new(&net, dim);
            let mut d = vec![vec![0.0f32; dim]; m];
            let g = |i: usize, di: &[f32]| q.grad(i, di);
            let pool = NodePool::new(threads);
            let calls = if threads == 1 {
                let mut gs = g;
                run_inner_with(
                    &cfg,
                    &mut net,
                    &TopK::new(0.3),
                    &mut rng,
                    &mut state,
                    &mut d,
                    GradFn::Serial(&mut gs),
                )
            } else {
                run_inner_with(
                    &cfg,
                    &mut net,
                    &TopK::new(0.3),
                    &mut rng,
                    &mut state,
                    &mut d,
                    GradFn::Parallel(&g, &pool),
                )
            };
            (calls, d)
        };
        let (c1, d1) = run_with_pool(1);
        for threads in [2, 4] {
            let (c, d) = run_with_pool(threads);
            assert_eq!(c, c1);
            assert_eq!(d, d1, "trajectory diverged at {threads} threads");
        }
    }
}
