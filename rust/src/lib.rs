//! # c2dfb
//!
//! Production-grade reproduction of **"A Communication and Computation
//! Efficient Fully First-order Method for Decentralized Bilevel
//! Optimization"** (C²DFB) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized coordinator: topologies and
//!   gossip mixing, contractive compressors with exact wire accounting,
//!   the reference-point compressed inner loop (Algorithm 2), gradient
//!   tracking, the C²DFB outer loop (Algorithm 1), the second-order
//!   baselines (MADSBO, MDBO) and the C²DFB(nc) ablation, plus the
//!   experiment harnesses for every table/figure in the paper.
//! * **L2 (python/compile, build-time only)** — JAX oracle bundles per
//!   task, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots, verified against pure-jnp oracles.
//!
//! The request path is pure Rust: artifacts are loaded through the PJRT C
//! API ([`runtime`], behind the `pjrt` cargo feature), Python never runs
//! after `make artifacts`.
//!
//! ## Running experiments
//!
//! The single entry point is the fluent [`Runner`]
//! (`Runner::new(&cfg).task(&task).run()`, or `.shared_task(..)` /
//! `.registry(..)`): algorithms implement the step-driven
//! [`algorithms::BilevelAlgorithm`] trait and the runner owns the outer
//! loop — evaluation cadence, [`metrics::StopCondition`] budgets
//! (rounds, communication MB, first-order oracles, target accuracy,
//! wall/sim time; the `[stop]` config table), and
//! [`algorithms::RunObserver`] streaming callbacks.  The stop reason is
//! recorded in [`metrics::RunMetrics`].  See `docs/API.md` for the full
//! surface and the migration table from the pre-`Runner` `run_with_*`
//! functions, and `c2dfb budget` for the equal-communication-budget
//! comparison harness.
//!
//! Batch execution lives one level up in [`coordinator::sweep`]: a
//! declarative scenario grid (algorithm × task × topology × compressor ×
//! partition × engine × stop) executed concurrently on a work-stealing
//! pool, bit-identical to serial at any width, with aggregated CSV/JSON
//! reports — `c2dfb sweep`, `docs/SWEEP.md`.  All experiment harnesses
//! and the goldens replay run through it.
//!
//! ## Transports
//!
//! Algorithms gossip through the [`collective::Transport`] trait and run
//! unmodified on either engine:
//!
//! * [`collective::Network`] — the synchronous in-process loop the paper's
//!   harnesses use: every message delivered, per-round cost model.
//! * [`sim::SimNetwork`] — a deterministic discrete-event engine with
//!   per-link latency/bandwidth/jitter, message loss, stragglers, and
//!   time-varying topology schedules (the `[network]` config table /
//!   `c2dfb netsweep`).  With a benign config it reproduces the
//!   synchronous trajectories bit-for-bit; see `docs/SIM.md`.
//!
//! Per-node compute (oracle calls) can additionally run on a scoped
//! thread pool ([`sim::NodePool`], `network.threads` config) with
//! node-ordered reductions, so results are identical at any thread count.
//!
//! ## Native tasks and golden traces
//!
//! Three task implementations need no artifacts and run on any build:
//! the analytic [`tasks::QuadraticTask`], the hyperparameter-tuning
//! [`tasks::LogRegTask`] and the [`tasks::HyperRepTask`] linear
//! hyper-representation (see `docs/TASKS.md`).  Their trajectories are
//! pinned by the [`goldens`] regression fixtures (`c2dfb goldens
//! [--bless]`, `tests/golden.rs`): exact byte/oracle accounting plus
//! 1e-9-relative losses across the full algorithm × task × topology ×
//! engine matrix.

pub mod algorithms;
pub mod analysis;
pub mod collective;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod goldens;
pub mod linalg;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod runtime;
pub mod sim;
pub mod tasks;
pub mod topology;
pub mod util;

pub use crate::coordinator::Runner;
