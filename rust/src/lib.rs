//! # c2dfb
//!
//! Production-grade reproduction of **"A Communication and Computation
//! Efficient Fully First-order Method for Decentralized Bilevel
//! Optimization"** (C²DFB) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the decentralized coordinator: topologies and
//!   gossip mixing, contractive compressors with exact wire accounting,
//!   the reference-point compressed inner loop (Algorithm 2), gradient
//!   tracking, the C²DFB outer loop (Algorithm 1), the second-order
//!   baselines (MADSBO, MDBO) and the C²DFB(nc) ablation, plus the
//!   experiment harnesses for every table/figure in the paper.
//! * **L2 (python/compile, build-time only)** — JAX oracle bundles per
//!   task, AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot-spots, verified against pure-jnp oracles.
//!
//! The request path is pure Rust: artifacts are loaded through the PJRT C
//! API ([`runtime`]), Python never runs after `make artifacts`.

pub mod algorithms;
pub mod collective;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod tasks;
pub mod topology;
pub mod util;
