//! Native (pure-Rust, no-PJRT) bilevel hyperparameter-tuning task: the
//! paper's "coefficient tuning" workload with every oracle evaluated
//! in-process.
//!
//! Per node i, with a multiclass logistic-regression head
//! W ∈ R^{d×c} (the lower variable, flattened row-major) and
//! per-coordinate log-regularization weights x ∈ R^d (the upper variable):
//!
//!   g_i(x, W) = CE(W; train_i) + ½ Σ_k r₀·exp(x_k) ‖W_{k·}‖²
//!   f_i(x, W) = CE(W; val_i)
//!
//! i.e. the lower level fits a regularized classifier on the node's train
//! shard and the upper level tunes the d regularization coefficients
//! against the validation shard (∇_x f ≡ 0, like the artifact preset).
//! All eight [`BilevelTask`] oracles — including the HVP/JVP the
//! second-order baselines pay for — are closed-form softmax-CE algebra,
//! so the task runs identically with or without the `pjrt` feature.
//!
//! Data is a [`newsgroups_like`](crate::data::newsgroups_like) corpus
//! partitioned across nodes by any [`Partition`] (including the
//! Dirichlet-α label-skew knob); everything is seeded through
//! [`crate::util::rng::Rng`], so a `(config, seed)` pair reproduces the
//! trajectory bit-for-bit — this is what the golden-trace fixtures pin.
//!
//! Generic over the payload [`Scalar`]: the corpus is always generated at
//! `f32` (same RNG stream at every dtype) and the staged shards are
//! widened exactly, so `dtype = "f64"` runs the same data through
//! higher-precision oracle arithmetic.

use super::{resize_guarded, widen, BilevelTask};
use crate::data::{newsgroups_like, partition::Partition, Dataset};
use crate::linalg::{kernels, Scalar};
use crate::util::rng::Rng;
use anyhow::Result;

/// One node's staged shards (row-major features, class labels).
struct Shard<S: Scalar> {
    n: usize,
    features: Vec<S>,
    labels: Vec<usize>,
}

impl<S: Scalar> Shard<S> {
    fn stage(ds: &Dataset) -> Shard<S> {
        Shard { n: ds.n, features: widen(&ds.features), labels: ds.labels.clone() }
    }

    fn row(&self, i: usize, d: usize) -> &[S] {
        &self.features[i * d..(i + 1) * d]
    }
}

pub struct LogRegTask<S: Scalar = f32> {
    m: usize,
    /// Feature dimension d (= upper dimension).
    pub features: usize,
    pub classes: usize,
    /// Base regularization scale r₀ (per-coordinate weight is r₀·exp(x_k)).
    pub reg0: S,
    train: Vec<Shard<S>>,
    val: Vec<Shard<S>>,
}

impl<S: Scalar> LogRegTask<S> {
    /// Generate the synthetic corpus, split train/val, partition the train
    /// side with `partition` (validation is split IID so the eval metric
    /// is comparable across nodes — the artifact-task protocol), and
    /// resize every shard to the static per-node sizes.
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        m: usize,
        features: usize,
        classes: usize,
        n_train: usize,
        n_val: usize,
        partition: Partition,
        noise: f32,
        seed: u64,
    ) -> LogRegTask<S> {
        let mut rng = Rng::new(seed);
        let need_tr = m * n_train;
        let need_val = m * n_val;
        let global = newsgroups_like(
            (need_tr + need_val) * 3 / 2,
            features,
            classes,
            noise,
            rng.next_u64(),
        );
        let (train_pool, val_pool) =
            global.split(need_tr as f64 / (need_tr + need_val) as f64, &mut rng);
        let train_shards = partition.split(&train_pool, m, &mut rng);
        let val_shards = Partition::Iid.split(&val_pool, m, &mut rng);
        let train = train_shards
            .iter()
            .map(|s| Shard::stage(&resize_guarded(s, &train_pool, n_train, &mut rng)))
            .collect();
        let val = val_shards
            .iter()
            .map(|s| Shard::stage(&resize_guarded(s, &val_pool, n_val, &mut rng)))
            .collect();
        LogRegTask { m, features, classes, reg0: S::from_f64(0.1), train, val }
    }

    /// CE loss, accuracy and (optionally) the CE gradient over a shard at
    /// head `w` (d×c row-major).  One fused pass: logits → stabilized
    /// softmax → loss/acc, plus the rank-1 gradient update per row.
    fn ce_pass(&self, shard: &Shard<S>, w: &[S], mut grad: Option<&mut [S]>) -> (f64, f64) {
        let (d, c) = (self.features, self.classes);
        let mut loss = 0.0f64;
        let mut hits = 0usize;
        let mut p = vec![S::ZERO; c];
        for r in 0..shard.n {
            let a = shard.row(r, d);
            softmax_logits(a, w, d, c, &mut p);
            let label = shard.labels[r];
            loss += -p[label].max(S::from_f64(1e-30)).to_f64().ln();
            let pred = argmax(&p);
            if pred == label {
                hits += 1;
            }
            if let Some(g) = grad.as_deref_mut() {
                // ∇_W CE for one sample: a · (p − onehot)ᵀ.
                p[label] -= S::ONE;
                for (k, &ak) in a.iter().enumerate() {
                    if ak != S::ZERO {
                        let gk = &mut g[k * c..(k + 1) * c];
                        for (gkc, &pc) in gk.iter_mut().zip(p.iter()) {
                            *gkc += ak * pc;
                        }
                    }
                }
            }
        }
        let n = shard.n.max(1);
        if let Some(g) = grad {
            let ns = S::from_usize(n);
            for v in g.iter_mut() {
                *v /= ns;
            }
        }
        (loss / n as f64, hits as f64 / n as f64)
    }

    /// ∇_y g_i = ∇_W CE(train) + r₀ exp(x_k) W_{k·} (the regularized
    /// lower-level gradient).
    fn grad_g(&self, i: usize, x: &[S], w: &[S]) -> Vec<S> {
        let (d, c) = (self.features, self.classes);
        let mut g = vec![S::ZERO; d * c];
        self.ce_pass(&self.train[i], w, Some(&mut g[..]));
        for k in 0..d {
            let r = self.reg0 * x[k].exp();
            for j in 0..c {
                g[k * c + j] += r * w[k * c + j];
            }
        }
        g
    }

    /// (∇_x g_i)_k = ½ r₀ exp(x_k) ‖W_{k·}‖².
    fn grad_x_g(&self, x: &[S], w: &[S]) -> Vec<S> {
        let (d, c) = (self.features, self.classes);
        let half = S::from_f64(0.5);
        (0..d)
            .map(|k| {
                let row_sq = w[k * c..(k + 1) * c]
                    .iter()
                    .fold(S::ZERO, |acc, &v| acc + v * v);
                half * self.reg0 * x[k].exp() * row_sq
            })
            .collect()
    }
}

/// `p = softmax(Wᵀ a)` with max-logit stabilization.
fn softmax_logits<S: Scalar>(a: &[S], w: &[S], d: usize, c: usize, p: &mut [S]) {
    p.fill(S::ZERO);
    for (k, &ak) in a.iter().enumerate().take(d) {
        if ak != S::ZERO {
            let wk = &w[k * c..(k + 1) * c];
            for (pj, &wkj) in p.iter_mut().zip(wk) {
                *pj += ak * wkj;
            }
        }
    }
    let mx = p.iter().cloned().fold(S::NEG_INFINITY, S::max);
    let mut sum = S::ZERO;
    for v in p.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    for v in p.iter_mut() {
        *v /= sum;
    }
}

fn argmax<S: Scalar>(p: &[S]) -> usize {
    let mut best = 0;
    for (j, &v) in p.iter().enumerate() {
        if v > p[best] {
            best = j;
        }
    }
    best
}

impl<S: Scalar> BilevelTask<S> for LogRegTask<S> {
    fn nodes(&self) -> usize {
        self.m
    }

    fn dx(&self) -> usize {
        self.features
    }

    fn dy(&self) -> usize {
        self.features * self.classes
    }

    fn name(&self) -> String {
        format!(
            "logreg(m={}, d={}, c={})",
            self.m, self.features, self.classes
        )
    }

    fn inner_y_grad(&self, i: usize, x: &[S], y: &[S], lambda: S) -> Result<Vec<S>> {
        // ∇_y h = ∇_y f + λ ∇_y g.
        let mut gf = vec![S::ZERO; self.dy()];
        self.ce_pass(&self.val[i], y, Some(&mut gf[..]));
        let gg = self.grad_g(i, x, y);
        kernels::axpy(lambda, &gg, &mut gf);
        Ok(gf)
    }

    fn inner_z_grad(&self, i: usize, x: &[S], z: &[S]) -> Result<Vec<S>> {
        Ok(self.grad_g(i, x, z))
    }

    fn hypergrad(&self, _i: usize, x: &[S], y: &[S], z: &[S], lambda: S) -> Result<Vec<S>> {
        // ∇_x f ≡ 0 here, so u = λ(∇_x g(x,y) − ∇_x g(x,z)); the reg term
        // is data-independent, hence identical on every node.
        let gy = self.grad_x_g(x, y);
        let gz = self.grad_x_g(x, z);
        Ok(gy
            .iter()
            .zip(&gz)
            .map(|(&a, &b)| lambda * (a - b))
            .collect())
    }

    fn eval(&self, i: usize, _x: &[S], y: &[S]) -> Result<(f64, f64)> {
        Ok(self.ce_pass(&self.val[i], y, None))
    }

    fn grad_y_f(&self, i: usize, _x: &[S], y: &[S]) -> Result<Vec<S>> {
        let mut g = vec![S::ZERO; self.dy()];
        self.ce_pass(&self.val[i], y, Some(&mut g[..]));
        Ok(g)
    }

    fn grad_x_f(&self, _i: usize, _x: &[S], _y: &[S]) -> Result<Vec<S>> {
        Ok(vec![S::ZERO; self.dx()])
    }

    fn hvp_yy_g(&self, i: usize, x: &[S], y: &[S], v: &[S]) -> Result<Vec<S>> {
        // Softmax-CE Hessian applied to V (per sample: with p = softmax,
        // du = Vᵀa, dp = (diag(p) − ppᵀ)du, contribution a·dpᵀ), plus the
        // diagonal regularizer r₀ exp(x_k).
        let (d, c) = (self.features, self.classes);
        let shard = &self.train[i];
        let mut out = vec![S::ZERO; d * c];
        let mut p = vec![S::ZERO; c];
        let mut du = vec![S::ZERO; c];
        for r in 0..shard.n {
            let a = shard.row(r, d);
            softmax_logits(a, y, d, c, &mut p);
            du.fill(S::ZERO);
            for (k, &ak) in a.iter().enumerate() {
                if ak != S::ZERO {
                    let vk = &v[k * c..(k + 1) * c];
                    for (dj, &vkj) in du.iter_mut().zip(vk) {
                        *dj += ak * vkj;
                    }
                }
            }
            let pdu = p
                .iter()
                .zip(&du)
                .fold(S::ZERO, |acc, (&a, &b)| acc + a * b);
            // dp_j = p_j (du_j − pᵀdu)
            for (k, &ak) in a.iter().enumerate() {
                if ak != S::ZERO {
                    let ok = &mut out[k * c..(k + 1) * c];
                    for ((oj, &pj), &dj) in ok.iter_mut().zip(&p).zip(&du) {
                        *oj += ak * pj * (dj - pdu);
                    }
                }
            }
        }
        let n = S::from_usize(shard.n.max(1));
        for o in out.iter_mut() {
            *o /= n;
        }
        for k in 0..d {
            let reg = self.reg0 * x[k].exp();
            for j in 0..c {
                out[k * c + j] += reg * v[k * c + j];
            }
        }
        Ok(out)
    }

    fn jvp_xy_g(&self, _i: usize, x: &[S], y: &[S], v: &[S]) -> Result<Vec<S>> {
        // ∂²g/∂x_k∂W_{k·} = r₀ exp(x_k) W_{k·}; contraction with v ∈ R^{dy}.
        let (d, c) = (self.features, self.classes);
        Ok((0..d)
            .map(|k| {
                let dot = y[k * c..(k + 1) * c]
                    .iter()
                    .zip(&v[k * c..(k + 1) * c])
                    .fold(S::ZERO, |acc, (&a, &b)| acc + a * b);
                self.reg0 * x[k].exp() * dot
            })
            .collect())
    }

    fn init_x(&self, _rng: &mut Rng) -> Vec<S> {
        // Log-weights start at 0 ⇒ per-coordinate reg weight r₀·exp(0).
        vec![S::ZERO; self.dx()]
    }

    fn init_y(&self, _rng: &mut Rng) -> Vec<S> {
        vec![S::ZERO; self.dy()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> LogRegTask {
        LogRegTask::generate(3, 10, 3, 20, 12, Partition::Dirichlet { alpha: 0.5 }, 0.3, 5)
    }

    fn rand_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
    }

    /// Scalar h = f + λg via the public oracles is impossible (losses are
    /// only exposed through eval); rebuild g's scalar here for FD checks.
    fn g_scalar(t: &LogRegTask, i: usize, x: &[f32], w: &[f32]) -> f64 {
        let (loss, _) = t.ce_pass(&t.train[i], w, None);
        let c = t.classes;
        let reg: f64 = (0..t.features)
            .map(|k| {
                let row_sq: f64 = w[k * c..(k + 1) * c]
                    .iter()
                    .map(|v| (*v as f64).powi(2))
                    .sum();
                0.5 * (t.reg0 as f64) * (x[k] as f64).exp() * row_sq
            })
            .sum();
        loss + reg
    }

    #[test]
    fn inner_z_grad_matches_finite_difference() {
        let t = task();
        let mut rng = Rng::new(1);
        let x = rand_vec(&mut rng, t.dx(), 0.3);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let g = t.inner_z_grad(0, &x, &w).unwrap();
        let eps = 1e-3f32;
        for k in [0usize, 7, t.dy() - 1] {
            let mut wp = w.clone();
            wp[k] += eps;
            let mut wm = w.clone();
            wm[k] -= eps;
            let fd = (g_scalar(&t, 0, &x, &wp) - g_scalar(&t, 0, &x, &wm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[k] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                g[k]
            );
        }
    }

    #[test]
    fn grad_x_g_matches_finite_difference() {
        let t = task();
        let mut rng = Rng::new(2);
        let x = rand_vec(&mut rng, t.dx(), 0.3);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let gx = t.grad_x_g(&x, &w);
        let eps = 1e-3f32;
        for k in 0..t.dx() {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let fd = (g_scalar(&t, 1, &xp, &w) - g_scalar(&t, 1, &xm, &w)) / (2.0 * eps as f64);
            assert!(
                (fd - gx[k] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                gx[k]
            );
        }
    }

    #[test]
    fn hvp_matches_finite_difference_of_gradient() {
        let t = task();
        let mut rng = Rng::new(3);
        let x = rand_vec(&mut rng, t.dx(), 0.3);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let v = rand_vec(&mut rng, t.dy(), 1.0);
        let hv = t.hvp_yy_g(0, &x, &w, &v).unwrap();
        let eps = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let wm: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let gp = t.inner_z_grad(0, &x, &wp).unwrap();
        let gm = t.inner_z_grad(0, &x, &wm).unwrap();
        for k in 0..t.dy() {
            let fd = (gp[k] - gm[k]) / (2.0 * eps);
            assert!(
                (fd - hv[k]).abs() < 5e-2 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                hv[k]
            );
        }
    }

    #[test]
    fn jvp_matches_finite_difference_cross_derivative() {
        let t = task();
        let mut rng = Rng::new(4);
        let x = rand_vec(&mut rng, t.dx(), 0.3);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let v = rand_vec(&mut rng, t.dy(), 1.0);
        let jv = t.jvp_xy_g(0, &x, &w, &v).unwrap();
        // (∇_x g(x, w + εv) − ∇_x g(x, w − εv)) / 2ε ≈ (∇²_xy g)·v.
        let eps = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let wm: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let gp = t.grad_x_g(&x, &wp);
        let gm = t.grad_x_g(&x, &wm);
        for k in 0..t.dx() {
            let fd = (gp[k] - gm[k]) / (2.0 * eps);
            assert!(
                (fd - jv[k]).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                jv[k]
            );
        }
    }

    #[test]
    fn hypergrad_is_lambda_scaled_reg_difference() {
        let t = task();
        let mut rng = Rng::new(5);
        let x = rand_vec(&mut rng, t.dx(), 0.3);
        let y = rand_vec(&mut rng, t.dy(), 0.4);
        let z = rand_vec(&mut rng, t.dy(), 0.4);
        let u = t.hypergrad(0, &x, &y, &z, 10.0).unwrap();
        let gy = t.grad_x_g(&x, &y);
        let gz = t.grad_x_g(&x, &z);
        for k in 0..t.dx() {
            assert!((u[k] - 10.0 * (gy[k] - gz[k])).abs() < 1e-5);
        }
        // y = z ⇒ zero hypergradient (no upper coupling through f).
        let u0 = t.hypergrad(0, &x, &y, &y, 10.0).unwrap();
        assert!(u0.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn gradient_descent_on_lower_level_reduces_train_loss() {
        let t = task();
        let x = vec![0.0f32; t.dx()];
        let mut w = vec![0.0f32; t.dy()];
        let loss0 = g_scalar(&t, 0, &x, &w);
        for _ in 0..60 {
            let g = t.inner_z_grad(0, &x, &w).unwrap();
            for (wk, gk) in w.iter_mut().zip(&g) {
                *wk -= 0.5 * gk;
            }
        }
        let loss1 = g_scalar(&t, 0, &x, &w);
        assert!(loss1 < loss0 * 0.9, "{loss0} -> {loss1}");
    }

    #[test]
    fn iid_trained_head_beats_chance_on_validation() {
        // Use an IID split so node 0's train shard covers every class (a
        // Dirichlet shard may be near single-class by design).
        let t: LogRegTask = LogRegTask::generate(3, 10, 3, 30, 15, Partition::Iid, 0.3, 8);
        let x = vec![0.0f32; t.dx()];
        let mut w = vec![0.0f32; t.dy()];
        for _ in 0..150 {
            let g = t.inner_z_grad(0, &x, &w).unwrap();
            for (wk, gk) in w.iter_mut().zip(&g) {
                *wk -= 0.5 * gk;
            }
        }
        let (loss, acc) = t.eval(0, &x, &w).unwrap();
        assert!(loss.is_finite());
        assert!(acc > 1.0 / 3.0, "val acc {acc} not above chance");
    }

    #[test]
    fn deterministic_by_seed_and_shard_shapes() {
        let a = task();
        let b = task();
        assert_eq!(a.train[0].features, b.train[0].features);
        assert_eq!(a.val[2].labels, b.val[2].labels);
        for i in 0..3 {
            assert_eq!(a.train[i].n, 20);
            assert_eq!(a.val[i].n, 12);
        }
        let mut rng = Rng::new(9);
        assert_eq!(a.init_x(&mut rng), vec![0.0; a.dx()]);
        assert_eq!(a.init_y(&mut rng).len(), a.dy());
    }

    /// The f64 task stages exactly-widened shards (same RNG stream) and
    /// its lower-level gradient agrees with the f32 one within f32
    /// rounding — the dtype-envelope contract at the task layer.
    #[test]
    fn f64_shards_widen_f32_shards_exactly() {
        let t32 = task();
        let t64: LogRegTask<f64> =
            LogRegTask::generate(3, 10, 3, 20, 12, Partition::Dirichlet { alpha: 0.5 }, 0.3, 5);
        for i in 0..3 {
            assert_eq!(t32.train[i].labels, t64.train[i].labels);
            for (a, &b) in t32.train[i].features.iter().zip(&t64.train[i].features) {
                assert_eq!(*a as f64, b);
            }
        }
        let mut rng = Rng::new(1);
        let x = rand_vec(&mut rng, t32.dx(), 0.3);
        let w = rand_vec(&mut rng, t32.dy(), 0.4);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let w64: Vec<f64> = w.iter().map(|&v| v as f64).collect();
        let g32 = t32.inner_z_grad(0, &x, &w).unwrap();
        let g64 = t64.inner_z_grad(0, &x64, &w64).unwrap();
        for (a, b) in g32.iter().zip(&g64) {
            let rel = (*a as f64 - b).abs() / (1.0 + b.abs());
            assert!(rel < 1e-5, "f32 {a} vs f64 {b}");
        }
    }
}
