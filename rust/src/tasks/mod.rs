//! Bilevel task abstraction: the per-node oracle bundle the algorithms
//! consume.
//!
//! * [`PjrtTask`] — the real thing: oracles are AOT-compiled HLO artifacts
//!   executed via PJRT ([`crate::runtime`]), per-node data shards staged as
//!   device buffers once at construction.
//! * [`quadratic::QuadraticTask`] — a fully analytic bilevel quadratic used
//!   by the convergence tests and benchmarks (no artifacts needed, known
//!   closed-form hyper-objective).
//! * [`logreg::LogRegTask`] — native hyperparameter tuning: per-coordinate
//!   ℓ2 weights (upper) over node-local multiclass logistic regression
//!   (lower).  Pure Rust, no artifacts; see `docs/TASKS.md`.
//! * [`hyperrep::HyperRepTask`] — native linear hyper-representation: a
//!   shared embedding (upper) over per-node ridge heads (lower).
//!
//! The native tasks accept any [`crate::data::partition::Partition`]
//! (including the Dirichlet-α label-skew knob) and are seeded for
//! bit-reproducibility — the golden-trace fixtures ([`crate::goldens`])
//! pin their trajectories.

pub mod hyperrep;
pub mod logreg;
pub mod pjrt;
pub mod quadratic;

pub use hyperrep::HyperRepTask;
pub use logreg::LogRegTask;
pub use pjrt::PjrtTask;
pub use quadratic::QuadraticTask;

use anyhow::Result;

/// Per-node bilevel oracle bundle.  All vectors are flat `f32`; `i` indexes
/// the node (each node sees only its own data shard).
pub trait BilevelTask {
    fn nodes(&self) -> usize;
    /// Upper-level dimension (x).
    fn dx(&self) -> usize;
    /// Lower-level dimension (y and z).
    fn dy(&self) -> usize;
    fn name(&self) -> String;

    /// ∇_y h_i(x, y) with h = f + λ g (the C²DFB y-sequence oracle).
    fn inner_y_grad(&self, i: usize, x: &[f32], y: &[f32], lambda: f32) -> Result<Vec<f32>>;
    /// ∇_y g_i(x, z) (the z-sequence oracle).
    fn inner_z_grad(&self, i: usize, x: &[f32], z: &[f32]) -> Result<Vec<f32>>;
    /// Fully first-order hypergradient estimate u_i (paper Eq. 4).
    fn hypergrad(&self, i: usize, x: &[f32], y: &[f32], z: &[f32], lambda: f32)
        -> Result<Vec<f32>>;
    /// Upper-level (validation) loss and accuracy at (x, y).
    fn eval(&self, i: usize, x: &[f32], y: &[f32]) -> Result<(f64, f64)>;

    // ---- second-order oracles (used only by the baselines) -------------
    fn grad_y_f(&self, i: usize, x: &[f32], y: &[f32]) -> Result<Vec<f32>>;
    fn grad_x_f(&self, i: usize, x: &[f32], y: &[f32]) -> Result<Vec<f32>>;
    /// (∇²_yy g_i) · v.
    fn hvp_yy_g(&self, i: usize, x: &[f32], y: &[f32], v: &[f32]) -> Result<Vec<f32>>;
    /// (∇²_xy g_i) · v  (v ∈ R^dy, result ∈ R^dx).
    fn jvp_xy_g(&self, i: usize, x: &[f32], y: &[f32], v: &[f32]) -> Result<Vec<f32>>;

    /// Initial upper/lower parameters (same on every node, like the paper).
    fn init_x(&self, rng: &mut crate::util::rng::Rng) -> Vec<f32>;
    fn init_y(&self, rng: &mut crate::util::rng::Rng) -> Vec<f32>;
}

/// Resize a partitioned shard to exactly `n` rows; an empty shard
/// (possible under extreme label skew, e.g. tiny Dirichlet α) falls back
/// to sampling from the global pool so every node keeps a working oracle.
/// Shared by the native data tasks' `generate` constructors.
pub(crate) fn resize_guarded(
    shard: &crate::data::Dataset,
    pool: &crate::data::Dataset,
    n: usize,
    rng: &mut crate::util::rng::Rng,
) -> crate::data::Dataset {
    if shard.n > 0 {
        shard.resize_to(n, rng)
    } else {
        pool.resize_to(n, rng)
    }
}

/// Average eval over all nodes at per-node parameters.
pub fn eval_mean(
    task: &dyn BilevelTask,
    xs: &[Vec<f32>],
    ys: &[Vec<f32>],
) -> Result<(f64, f64)> {
    let m = task.nodes();
    let (mut loss, mut acc) = (0.0, 0.0);
    for i in 0..m {
        let (l, a) = task.eval(i, &xs[i], &ys[i])?;
        loss += l;
        acc += a;
    }
    Ok((loss / m as f64, acc / m as f64))
}

/// Eval the CONSENSUS model (x̄, ȳ) on every node's validation shard and
/// average — the paper's "upper-level test accuracy" protocol (a single
/// global model, as standard in decentralized FL evaluations).
pub fn eval_consensus(
    task: &dyn BilevelTask,
    xs: &[Vec<f32>],
    ys: &[Vec<f32>],
) -> Result<(f64, f64)> {
    let xbar = crate::linalg::mean_rows(&xs.to_vec());
    let ybar = crate::linalg::mean_rows(&ys.to_vec());
    let m = task.nodes();
    let (mut loss, mut acc) = (0.0, 0.0);
    for i in 0..m {
        let (l, a) = task.eval(i, &xbar, &ybar)?;
        loss += l;
        acc += a;
    }
    Ok((loss / m as f64, acc / m as f64))
}
