//! Bilevel task abstraction: the per-node oracle bundle the algorithms
//! consume.
//!
//! * [`PjrtTask`] — the real thing: oracles are AOT-compiled HLO artifacts
//!   executed via PJRT ([`crate::runtime`]), per-node data shards staged as
//!   device buffers once at construction.  Artifacts are `f32`; the task
//!   implements [`BilevelTask`] at the default dtype only (the coordinator
//!   rejects `dtype = "f64"` for artifact tasks up front).
//! * [`quadratic::QuadraticTask`] — a fully analytic bilevel quadratic used
//!   by the convergence tests and benchmarks (no artifacts needed, known
//!   closed-form hyper-objective).
//! * [`logreg::LogRegTask`] — native hyperparameter tuning: per-coordinate
//!   ℓ2 weights (upper) over node-local multiclass logistic regression
//!   (lower).  Pure Rust, no artifacts; see `docs/TASKS.md`.
//! * [`hyperrep::HyperRepTask`] — native linear hyper-representation: a
//!   shared embedding (upper) over per-node ridge heads (lower).
//!
//! The native tasks are generic over the payload [`Scalar`] `S`
//! (docs/DTYPE.md).  Data generation and initialization always draw at
//! `f32` through the same RNG streams regardless of dtype; staged shards
//! and parameters are then widened exactly (`f32 → S` is lossless), so an
//! `f64` run solves the *same problem instance* as the `f32` run — only
//! the oracle arithmetic and the wire payloads change precision.  At
//! `S = f32` the widening is the identity and every byte matches the
//! historical path.
//!
//! The native tasks accept any [`crate::data::partition::Partition`]
//! (including the Dirichlet-α label-skew knob) and are seeded for
//! bit-reproducibility — the golden-trace fixtures ([`crate::goldens`])
//! pin their trajectories.

pub mod hyperrep;
pub mod logreg;
pub mod pjrt;
pub mod quadratic;

pub use hyperrep::HyperRepTask;
pub use logreg::LogRegTask;
pub use pjrt::PjrtTask;
pub use quadratic::QuadraticTask;

use crate::linalg::Scalar;
use anyhow::Result;

/// Per-node bilevel oracle bundle at payload scalar `S`.  All vectors are
/// flat; `i` indexes the node (each node sees only its own data shard).
pub trait BilevelTask<S: Scalar = f32> {
    fn nodes(&self) -> usize;
    /// Upper-level dimension (x).
    fn dx(&self) -> usize;
    /// Lower-level dimension (y and z).
    fn dy(&self) -> usize;
    fn name(&self) -> String;

    /// ∇_y h_i(x, y) with h = f + λ g (the C²DFB y-sequence oracle).
    fn inner_y_grad(&self, i: usize, x: &[S], y: &[S], lambda: S) -> Result<Vec<S>>;
    /// ∇_y g_i(x, z) (the z-sequence oracle).
    fn inner_z_grad(&self, i: usize, x: &[S], z: &[S]) -> Result<Vec<S>>;
    /// Fully first-order hypergradient estimate u_i (paper Eq. 4).
    fn hypergrad(&self, i: usize, x: &[S], y: &[S], z: &[S], lambda: S) -> Result<Vec<S>>;
    /// Upper-level (validation) loss and accuracy at (x, y).
    fn eval(&self, i: usize, x: &[S], y: &[S]) -> Result<(f64, f64)>;

    // ---- second-order oracles (used only by the baselines) -------------
    fn grad_y_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>>;
    fn grad_x_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>>;
    /// (∇²_yy g_i) · v.
    fn hvp_yy_g(&self, i: usize, x: &[S], y: &[S], v: &[S]) -> Result<Vec<S>>;
    /// (∇²_xy g_i) · v  (v ∈ R^dy, result ∈ R^dx).
    fn jvp_xy_g(&self, i: usize, x: &[S], y: &[S], v: &[S]) -> Result<Vec<S>>;

    /// Initial upper/lower parameters (same on every node, like the paper).
    fn init_x(&self, rng: &mut crate::util::rng::Rng) -> Vec<S>;
    fn init_y(&self, rng: &mut crate::util::rng::Rng) -> Vec<S>;
}

/// Widen an `f32`-generated vector into the payload scalar (exact; the
/// identity at `S = f32`).  All native-task staging funnels through this
/// so the "same instance, higher precision" contract lives in one place.
pub(crate) fn widen<S: Scalar>(v: &[f32]) -> Vec<S> {
    v.iter().map(|&x| S::from_f64(x as f64)).collect()
}

/// Resize a partitioned shard to exactly `n` rows; an empty shard
/// (possible under extreme label skew, e.g. tiny Dirichlet α) falls back
/// to sampling from the global pool so every node keeps a working oracle.
/// Shared by the native data tasks' `generate` constructors.
pub(crate) fn resize_guarded(
    shard: &crate::data::Dataset,
    pool: &crate::data::Dataset,
    n: usize,
    rng: &mut crate::util::rng::Rng,
) -> crate::data::Dataset {
    if shard.n > 0 {
        shard.resize_to(n, rng)
    } else {
        pool.resize_to(n, rng)
    }
}

/// Average eval over all nodes at per-node parameters.
pub fn eval_mean<S: Scalar>(
    task: &dyn BilevelTask<S>,
    xs: &[Vec<S>],
    ys: &[Vec<S>],
) -> Result<(f64, f64)> {
    let m = task.nodes();
    let (mut loss, mut acc) = (0.0, 0.0);
    for i in 0..m {
        let (l, a) = task.eval(i, &xs[i], &ys[i])?;
        loss += l;
        acc += a;
    }
    Ok((loss / m as f64, acc / m as f64))
}

/// Eval the CONSENSUS model (x̄, ȳ) on every node's validation shard and
/// average — the paper's "upper-level test accuracy" protocol (a single
/// global model, as standard in decentralized FL evaluations).
pub fn eval_consensus<S: Scalar>(
    task: &dyn BilevelTask<S>,
    xs: &[Vec<S>],
    ys: &[Vec<S>],
) -> Result<(f64, f64)> {
    let xbar = crate::linalg::mean_rows(xs);
    let ybar = crate::linalg::mean_rows(ys);
    let m = task.nodes();
    let (mut loss, mut acc) = (0.0, 0.0);
    for i in 0..m {
        let (l, a) = task.eval(i, &xbar, &ybar)?;
        loss += l;
        acc += a;
    }
    Ok((loss / m as f64, acc / m as f64))
}
