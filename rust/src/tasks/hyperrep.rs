//! Native (pure-Rust, no-PJRT) linear hyper-representation task.
//!
//! Per node i, with a shared linear embedding E ∈ R^{p×k} (the upper
//! variable, flattened row-major) and a regression head W ∈ R^{k×c} (the
//! lower variable):
//!
//!   g_i(E, W) = 1/(2n)‖A_tr E W − B_tr‖²_F + ρ/2 ‖W‖²_F
//!   f_i(E, W) = 1/(2n)‖A_val E W − B_val‖²_F
//!
//! i.e. the lower level ridge-fits a head on the node's embedded train
//! shard and the upper level learns the embedding that makes those heads
//! work on validation data — the paper's hyper-representation workload
//! with a linear backbone so every oracle (HVP/JVP included) is
//! closed-form matrix algebra.
//!
//! Data is an [`mnist_like`](crate::data::mnist_like) corpus regressed
//! onto one-hot labels, partitioned by any [`Partition`] (including
//! Dirichlet-α), seeded through [`crate::util::rng::Rng`] for
//! bit-reproducibility — the golden-trace fixtures pin these runs.
//!
//! Generic over the payload [`Scalar`]: data generation draws at `f32`
//! (identical RNG streams across dtypes), staged shards are widened
//! exactly, and the oracle matrix algebra runs at `S`.

use super::{resize_guarded, widen, BilevelTask};
use crate::data::{mnist_like, partition::Partition, Dataset};
use crate::linalg::Scalar;
use crate::util::rng::Rng;
use anyhow::Result;

struct Shard<S: Scalar> {
    n: usize,
    /// n×p features.
    a: Vec<S>,
    /// n×c one-hot targets.
    b: Vec<S>,
    labels: Vec<usize>,
}

impl<S: Scalar> Shard<S> {
    fn stage(ds: &Dataset) -> Shard<S> {
        Shard {
            n: ds.n,
            a: widen(&ds.features),
            b: widen(&ds.onehot()),
            labels: ds.labels.clone(),
        }
    }
}

pub struct HyperRepTask<S: Scalar = f32> {
    m: usize,
    /// Input feature dimension p.
    pub inputs: usize,
    /// Embedding dimension k.
    pub embed: usize,
    pub classes: usize,
    /// Head ridge coefficient ρ (keeps the lower level strongly convex).
    pub ridge: S,
    train: Vec<Shard<S>>,
    val: Vec<Shard<S>>,
}

impl<S: Scalar> HyperRepTask<S> {
    #[allow(clippy::too_many_arguments)]
    pub fn generate(
        m: usize,
        inputs: usize,
        embed: usize,
        classes: usize,
        n_train: usize,
        n_val: usize,
        partition: Partition,
        noise: f32,
        seed: u64,
    ) -> HyperRepTask<S> {
        let mut rng = Rng::new(seed);
        let need_tr = m * n_train;
        let need_val = m * n_val;
        let global = mnist_like(
            (need_tr + need_val) * 3 / 2,
            inputs,
            classes,
            noise,
            rng.next_u64(),
        );
        let (train_pool, val_pool) =
            global.split(need_tr as f64 / (need_tr + need_val) as f64, &mut rng);
        let train_shards = partition.split(&train_pool, m, &mut rng);
        let val_shards = Partition::Iid.split(&val_pool, m, &mut rng);
        let train = train_shards
            .iter()
            .map(|s| Shard::stage(&resize_guarded(s, &train_pool, n_train, &mut rng)))
            .collect();
        let val = val_shards
            .iter()
            .map(|s| Shard::stage(&resize_guarded(s, &val_pool, n_val, &mut rng)))
            .collect();
        HyperRepTask { m, inputs, embed, classes, ridge: S::from_f64(0.1), train, val }
    }

    /// Embedded features Z = A E (n×k) for a shard.
    fn embed_shard(&self, shard: &Shard<S>, e: &[S]) -> Vec<S> {
        let (p, k) = (self.inputs, self.embed);
        let mut z = vec![S::ZERO; shard.n * k];
        for r in 0..shard.n {
            let a = &shard.a[r * p..(r + 1) * p];
            let zr = &mut z[r * k..(r + 1) * k];
            for (j, &aj) in a.iter().enumerate() {
                if aj != S::ZERO {
                    let ej = &e[j * k..(j + 1) * k];
                    for (zc, &ejc) in zr.iter_mut().zip(ej) {
                        *zc += aj * ejc;
                    }
                }
            }
        }
        z
    }

    /// Residual R = Z W − B (n×c).
    fn residual(&self, shard: &Shard<S>, z: &[S], w: &[S]) -> Vec<S> {
        let (k, c) = (self.embed, self.classes);
        let mut r = vec![S::ZERO; shard.n * c];
        for row in 0..shard.n {
            let zr = &z[row * k..(row + 1) * k];
            let rr = &mut r[row * c..(row + 1) * c];
            for (j, &zj) in zr.iter().enumerate() {
                let wj = &w[j * c..(j + 1) * c];
                for (rc, &wjc) in rr.iter_mut().zip(wj) {
                    *rc += zj * wjc;
                }
            }
            for (rc, &bc) in rr.iter_mut().zip(&shard.b[row * c..(row + 1) * c]) {
                *rc -= bc;
            }
        }
        r
    }

    /// ∇_W [1/(2n)‖ZW − B‖²] = Zᵀ R / n (k×c).
    fn grad_w(&self, shard: &Shard<S>, z: &[S], r: &[S]) -> Vec<S> {
        let (k, c) = (self.embed, self.classes);
        let mut g = vec![S::ZERO; k * c];
        for row in 0..shard.n {
            let zr = &z[row * k..(row + 1) * k];
            let rr = &r[row * c..(row + 1) * c];
            for (j, &zj) in zr.iter().enumerate() {
                let gj = &mut g[j * c..(j + 1) * c];
                for (gc, &rc) in gj.iter_mut().zip(rr) {
                    *gc += zj * rc;
                }
            }
        }
        let n = S::from_usize(shard.n.max(1));
        for v in g.iter_mut() {
            *v /= n;
        }
        g
    }

    /// ∇_E [1/(2n)‖A E W − B‖²] = Aᵀ R Wᵀ / n (p×k).
    fn grad_e(&self, shard: &Shard<S>, r: &[S], w: &[S]) -> Vec<S> {
        let (p, k, c) = (self.inputs, self.embed, self.classes);
        // First S = R Wᵀ (n×k), then Aᵀ S.
        let mut g = vec![S::ZERO; p * k];
        let mut s_row = vec![S::ZERO; k];
        for row in 0..r.len() / c {
            let rr = &r[row * c..(row + 1) * c];
            s_row.fill(S::ZERO);
            for (j, sj) in s_row.iter_mut().enumerate() {
                let wj = &w[j * c..(j + 1) * c];
                *sj = rr
                    .iter()
                    .zip(wj)
                    .fold(S::ZERO, |acc, (&a, &b)| acc + a * b);
            }
            let a = &shard.a[row * p..(row + 1) * p];
            for (jf, &aj) in a.iter().enumerate() {
                if aj != S::ZERO {
                    let gj = &mut g[jf * k..(jf + 1) * k];
                    for (gc, &sc) in gj.iter_mut().zip(&s_row) {
                        *gc += aj * sc;
                    }
                }
            }
        }
        let n = S::from_usize(shard.n.max(1));
        for v in g.iter_mut() {
            *v /= n;
        }
        g
    }

    /// Unregularized ∇_W of ½/n‖A E W − B‖² on a shard.  Split from
    /// [`Self::grad_e_of`] so the inner loop (which only needs the head
    /// gradient) never pays the O(n·p·k) embedding-gradient product.
    fn grad_w_of(&self, shard: &Shard<S>, e: &[S], w: &[S]) -> Vec<S> {
        let z = self.embed_shard(shard, e);
        let r = self.residual(shard, &z, w);
        self.grad_w(shard, &z, &r)
    }

    /// Unregularized ∇_E of ½/n‖A E W − B‖² on a shard.
    fn grad_e_of(&self, shard: &Shard<S>, e: &[S], w: &[S]) -> Vec<S> {
        let z = self.embed_shard(shard, e);
        let r = self.residual(shard, &z, w);
        self.grad_e(shard, &r, w)
    }

    fn loss_of(&self, shard: &Shard<S>, e: &[S], w: &[S]) -> f64 {
        let z = self.embed_shard(shard, e);
        let r = self.residual(shard, &z, w);
        let n = shard.n.max(1) as f64;
        r.iter().map(|v| v.to_f64().powi(2)).sum::<f64>() / (2.0 * n)
    }
}

impl<S: Scalar> BilevelTask<S> for HyperRepTask<S> {
    fn nodes(&self) -> usize {
        self.m
    }

    fn dx(&self) -> usize {
        self.inputs * self.embed
    }

    fn dy(&self) -> usize {
        self.embed * self.classes
    }

    fn name(&self) -> String {
        format!(
            "hyperrep(m={}, p={}, k={}, c={})",
            self.m, self.inputs, self.embed, self.classes
        )
    }

    fn inner_y_grad(&self, i: usize, x: &[S], y: &[S], lambda: S) -> Result<Vec<S>> {
        let gf = self.grad_w_of(&self.val[i], x, y);
        let mut gg = self.grad_w_of(&self.train[i], x, y);
        for (g, &wv) in gg.iter_mut().zip(y) {
            *g += self.ridge * wv;
        }
        Ok(gf
            .iter()
            .zip(&gg)
            .map(|(&a, &b)| a + lambda * b)
            .collect())
    }

    fn inner_z_grad(&self, i: usize, x: &[S], z: &[S]) -> Result<Vec<S>> {
        let mut gg = self.grad_w_of(&self.train[i], x, z);
        for (g, &wv) in gg.iter_mut().zip(z) {
            *g += self.ridge * wv;
        }
        Ok(gg)
    }

    fn hypergrad(&self, i: usize, x: &[S], y: &[S], z: &[S], lambda: S) -> Result<Vec<S>> {
        // u = ∇_E f(x,y) + λ(∇_E g(x,y) − ∇_E g(x,z)); the ridge term has
        // no E-dependence.  The train-shard embedding Z = A·E depends only
        // on x, so compute it once for both penalty residuals.
        let gf_e = self.grad_e_of(&self.val[i], x, y);
        let train = &self.train[i];
        let zt = self.embed_shard(train, x);
        let gg_e_y = self.grad_e(train, &self.residual(train, &zt, y), y);
        let gg_e_z = self.grad_e(train, &self.residual(train, &zt, z), z);
        Ok(gf_e
            .iter()
            .zip(&gg_e_y)
            .zip(&gg_e_z)
            .map(|((&f, &gy), &gz)| f + lambda * (gy - gz))
            .collect())
    }

    fn eval(&self, i: usize, x: &[S], y: &[S]) -> Result<(f64, f64)> {
        let shard = &self.val[i];
        let loss = self.loss_of(shard, x, y);
        // Accuracy: argmax of the regressed one-hot scores.
        let (k, c) = (self.embed, self.classes);
        let z = self.embed_shard(shard, x);
        let mut hits = 0usize;
        for row in 0..shard.n {
            let zr = &z[row * k..(row + 1) * k];
            let mut best = 0usize;
            let mut best_v = S::NEG_INFINITY;
            for j in 0..c {
                let score = zr
                    .iter()
                    .enumerate()
                    .fold(S::ZERO, |acc, (t, &zt)| acc + zt * y[t * c + j]);
                if score > best_v {
                    best_v = score;
                    best = j;
                }
            }
            if best == shard.labels[row] {
                hits += 1;
            }
        }
        Ok((loss, hits as f64 / shard.n.max(1) as f64))
    }

    fn grad_y_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>> {
        Ok(self.grad_w_of(&self.val[i], x, y))
    }

    fn grad_x_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>> {
        Ok(self.grad_e_of(&self.val[i], x, y))
    }

    fn hvp_yy_g(&self, i: usize, x: &[S], _y: &[S], v: &[S]) -> Result<Vec<S>> {
        // The lower level is quadratic in W: H·V = ZᵀZV/n + ρV.
        let shard = &self.train[i];
        let z = self.embed_shard(shard, x);
        let (k, c) = (self.embed, self.classes);
        // ZV (n×c) without the −B shift, then Zᵀ(ZV)/n.
        let mut zv = vec![S::ZERO; shard.n * c];
        for row in 0..shard.n {
            let zr = &z[row * k..(row + 1) * k];
            let o = &mut zv[row * c..(row + 1) * c];
            for (j, &zj) in zr.iter().enumerate() {
                let vj = &v[j * c..(j + 1) * c];
                for (oc, &vjc) in o.iter_mut().zip(vj) {
                    *oc += zj * vjc;
                }
            }
        }
        let mut out = self.grad_w(shard, &z, &zv);
        for (o, &vv) in out.iter_mut().zip(v) {
            *o += self.ridge * vv;
        }
        Ok(out)
    }

    fn jvp_xy_g(&self, i: usize, x: &[S], y: &[S], v: &[S]) -> Result<Vec<S>> {
        // ∇_E g = Aᵀ(A E W − B)Wᵀ/n; directional derivative in W-direction
        // V: Aᵀ(A E V)Wᵀ/n + Aᵀ(A E W − B)Vᵀ/n.
        let shard = &self.train[i];
        let z = self.embed_shard(shard, x);
        let (k, c) = (self.embed, self.classes);
        // Term 1: residual' = Z V (no B), contracted against Wᵀ.
        let mut zv = vec![S::ZERO; shard.n * c];
        for row in 0..shard.n {
            let zr = &z[row * k..(row + 1) * k];
            let o = &mut zv[row * c..(row + 1) * c];
            for (j, &zj) in zr.iter().enumerate() {
                let vj = &v[j * c..(j + 1) * c];
                for (oc, &vjc) in o.iter_mut().zip(vj) {
                    *oc += zj * vjc;
                }
            }
        }
        let t1 = self.grad_e(shard, &zv, y);
        // Term 2: true residual contracted against Vᵀ.
        let r = self.residual(shard, &z, y);
        let t2 = self.grad_e(shard, &r, v);
        Ok(t1.iter().zip(&t2).map(|(&a, &b)| a + b).collect())
    }

    fn init_x(&self, rng: &mut Rng) -> Vec<S> {
        // He-style init for the linear backbone; f32 draws widened exactly
        // so every dtype starts from the same embedding.
        let std = (1.0 / self.inputs as f32).sqrt();
        (0..self.dx())
            .map(|_| S::from_f64(rng.normal_f32(0.0, std) as f64))
            .collect()
    }

    fn init_y(&self, _rng: &mut Rng) -> Vec<S> {
        vec![S::ZERO; self.dy()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task() -> HyperRepTask {
        HyperRepTask::generate(3, 9, 4, 3, 18, 10, Partition::Dirichlet { alpha: 0.5 }, 0.2, 6)
    }

    fn rand_vec(rng: &mut Rng, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
    }

    fn g_scalar(t: &HyperRepTask, i: usize, e: &[f32], w: &[f32]) -> f64 {
        t.loss_of(&t.train[i], e, w)
            + 0.5 * t.ridge as f64 * w.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
    }

    #[test]
    fn inner_z_grad_matches_finite_difference() {
        let t = task();
        let mut rng = Rng::new(1);
        let e = t.init_x(&mut rng);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let g = t.inner_z_grad(0, &e, &w).unwrap();
        let eps = 1e-3f32;
        for k in [0usize, 5, t.dy() - 1] {
            let mut wp = w.clone();
            wp[k] += eps;
            let mut wm = w.clone();
            wm[k] -= eps;
            let fd = (g_scalar(&t, 0, &e, &wp) - g_scalar(&t, 0, &e, &wm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[k] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                g[k]
            );
        }
    }

    #[test]
    fn grad_x_f_matches_finite_difference() {
        let t = task();
        let mut rng = Rng::new(2);
        let e = t.init_x(&mut rng);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let g = t.grad_x_f(1, &e, &w).unwrap();
        let eps = 1e-3f32;
        for k in [0usize, 11, t.dx() - 1] {
            let mut ep = e.clone();
            ep[k] += eps;
            let mut em = e.clone();
            em[k] -= eps;
            let fd = (t.loss_of(&t.val[1], &ep, &w) - t.loss_of(&t.val[1], &em, &w))
                / (2.0 * eps as f64);
            assert!(
                (fd - g[k] as f64).abs() < 2e-3 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                g[k]
            );
        }
    }

    #[test]
    fn hvp_matches_finite_difference_of_gradient() {
        let t = task();
        let mut rng = Rng::new(3);
        let e = t.init_x(&mut rng);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let v = rand_vec(&mut rng, t.dy(), 1.0);
        let hv = t.hvp_yy_g(0, &e, &w, &v).unwrap();
        let eps = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let wm: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let gp = t.inner_z_grad(0, &e, &wp).unwrap();
        let gm = t.inner_z_grad(0, &e, &wm).unwrap();
        for k in 0..t.dy() {
            let fd = (gp[k] - gm[k]) / (2.0 * eps);
            assert!(
                (fd - hv[k]).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                hv[k]
            );
        }
    }

    #[test]
    fn jvp_matches_finite_difference_cross_derivative() {
        let t = task();
        let mut rng = Rng::new(4);
        let e = t.init_x(&mut rng);
        let w = rand_vec(&mut rng, t.dy(), 0.4);
        let v = rand_vec(&mut rng, t.dy(), 1.0);
        let jv = t.jvp_xy_g(0, &e, &w, &v).unwrap();
        let eps = 1e-3f32;
        let wp: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let wm: Vec<f32> = w.iter().zip(&v).map(|(a, b)| a - eps * b).collect();
        let grad_e_at = |w_: &[f32]| -> Vec<f32> {
            let z = t.embed_shard(&t.train[0], &e);
            let r = t.residual(&t.train[0], &z, w_);
            t.grad_e(&t.train[0], &r, w_)
        };
        let gp = grad_e_at(&wp);
        let gm = grad_e_at(&wm);
        for k in 0..t.dx() {
            let fd = (gp[k] - gm[k]) / (2.0 * eps);
            assert!(
                (fd - jv[k]).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs {}",
                jv[k]
            );
        }
    }

    #[test]
    fn penalty_hypergrad_consistency() {
        // With y = z the penalty terms cancel and the hypergradient reduces
        // to ∇_E f — the fully first-order estimator's λ-independence check.
        let t = task();
        let mut rng = Rng::new(5);
        let e = t.init_x(&mut rng);
        let y = rand_vec(&mut rng, t.dy(), 0.4);
        let u1 = t.hypergrad(0, &e, &y, &y, 5.0).unwrap();
        let u2 = t.hypergrad(0, &e, &y, &y, 500.0).unwrap();
        let gf = t.grad_x_f(0, &e, &y).unwrap();
        for k in 0..t.dx() {
            assert!((u1[k] - gf[k]).abs() < 1e-5, "λ=5 coord {k}");
            assert!((u2[k] - gf[k]).abs() < 1e-5, "λ=500 coord {k}");
        }
    }

    #[test]
    fn lower_level_descent_reduces_train_loss() {
        let t = task();
        let mut rng = Rng::new(6);
        let e = t.init_x(&mut rng);
        let mut w = vec![0.0f32; t.dy()];
        let l0 = g_scalar(&t, 0, &e, &w);
        for _ in 0..80 {
            let g = t.inner_z_grad(0, &e, &w).unwrap();
            for (wk, gk) in w.iter_mut().zip(&g) {
                *wk -= 0.1 * gk;
            }
        }
        let l1 = g_scalar(&t, 0, &e, &w);
        assert!(l1 < l0 * 0.95, "{l0} -> {l1}");
    }

    #[test]
    fn shapes_and_determinism() {
        let a = task();
        let b = task();
        assert_eq!(a.dx(), 9 * 4);
        assert_eq!(a.dy(), 4 * 3);
        assert_eq!(a.train[0].a, b.train[0].a);
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        assert_eq!(a.init_x(&mut r1), b.init_x(&mut r2));
    }

    /// Same RNG streams at both dtypes: the f64 task's shards and init
    /// are exact widenings of the f32 task's.
    #[test]
    fn f64_task_is_exact_widening() {
        let t32 = task();
        let t64: HyperRepTask<f64> =
            HyperRepTask::generate(3, 9, 4, 3, 18, 10, Partition::Dirichlet { alpha: 0.5 }, 0.2, 6);
        for (a, &b) in t32.train[1].a.iter().zip(&t64.train[1].a) {
            assert_eq!(*a as f64, b);
        }
        let mut r1 = Rng::new(8);
        let mut r2 = Rng::new(8);
        let x32 = t32.init_x(&mut r1);
        let x64 = t64.init_x(&mut r2);
        for (a, &b) in x32.iter().zip(&x64) {
            assert_eq!(*a as f64, b);
        }
    }
}
