//! Analytic bilevel quadratic task (no PJRT) for tests and benches.
//!
//! Per node i (diagonal quadratics keep every oracle closed-form):
//!
//!   f_i(x, y) = ½‖y − P_i x − p_i‖²
//!   g_i(x, y) = ½ yᵀ diag(a_i) y − (Q_i x + q_i)ᵀ y     (a_i > 0)
//!
//! with P_i, Q_i diagonal (dx == dy).  Globally
//! y*(x) = (Q̄x + q̄) / ā coordinate-wise, and the hyper-objective
//! ψ(x) = ½‖y*(x) − P̄x − p̄‖² + const-ish cross terms is known, so tests
//! can check the hypergradient estimate against the analytic ∇ψ.
//!
//! Generic over the payload [`Scalar`]: coefficients are drawn at `f32`
//! (identical RNG stream at every dtype) and widened exactly, so the
//! `f64` instance is the same problem computed in higher precision —
//! which is what the f32-vs-f64 tolerance-envelope tests rely on.

use super::{widen, BilevelTask};
use crate::linalg::Scalar;
use crate::util::rng::Rng;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct QuadraticTask<S: Scalar = f32> {
    pub m: usize,
    pub dim: usize,
    /// Per node: diag of the LL Hessian (strong convexity aᵢ > 0).
    pub a: Vec<Vec<S>>,
    /// Per node: diag coupling Q_i and offset q_i of the LL problem.
    pub q_diag: Vec<Vec<S>>,
    pub q_off: Vec<Vec<S>>,
    /// Per node: diag P_i and offset p_i of the UL problem.
    pub p_diag: Vec<Vec<S>>,
    pub p_off: Vec<Vec<S>>,
}

impl<S: Scalar> QuadraticTask<S> {
    pub fn generate(m: usize, dim: usize, heterogeneity: f32, seed: u64) -> QuadraticTask<S> {
        let mut rng = Rng::new(seed);
        // Draw at f32 (dtype-independent streams), widen at the end.
        let mut per_node = |center: f32, spread: f32| -> Vec<Vec<f32>> {
            (0..m)
                .map(|_| {
                    (0..dim)
                        .map(|_| center + rng.normal_f32(0.0, spread))
                        .collect()
                })
                .collect()
        };
        let stage = |rows: Vec<Vec<f32>>| -> Vec<Vec<S>> {
            rows.iter().map(|r| widen(r)).collect()
        };
        QuadraticTask {
            m,
            dim,
            // Hessian diag in [0.5, 1.5]-ish, strictly positive.
            a: stage(
                per_node(1.0, 0.2 * heterogeneity)
                    .into_iter()
                    .map(|v| v.into_iter().map(|x| x.abs().max(0.3)).collect())
                    .collect(),
            ),
            q_diag: stage(per_node(0.8, 0.5 * heterogeneity)),
            q_off: stage(per_node(0.0, heterogeneity)),
            p_diag: stage(per_node(0.5, 0.5 * heterogeneity)),
            p_off: stage(per_node(0.0, heterogeneity)),
        }
    }

    fn mean_of(field: &[Vec<S>]) -> Vec<S> {
        crate::linalg::mean_rows(field)
    }

    /// Global lower-level solution y*(x) (coordinate-wise).
    pub fn y_star(&self, x: &[S]) -> Vec<S> {
        let a = Self::mean_of(&self.a);
        let qd = Self::mean_of(&self.q_diag);
        let qo = Self::mean_of(&self.q_off);
        (0..self.dim)
            .map(|k| (qd[k] * x[k] + qo[k]) / a[k])
            .collect()
    }

    /// Analytic hypergradient ∇ψ(x) of ψ(x) = f̄(x, y*(x)):
    /// ∇ψ = (dy*/dx)ᵀ ∇_y f̄ + ∇_x f̄ (all diagonal).  Note ∇_x f̄ needs the
    /// *second moments* of the per-node P_i:
    /// ∇_x f̄ = −(mean(pd) y* − mean(pd²) x − mean(pd·po)).
    pub fn hypergrad_analytic(&self, x: &[S]) -> Vec<S> {
        let a = Self::mean_of(&self.a);
        let qd = Self::mean_of(&self.q_diag);
        let pd = Self::mean_of(&self.p_diag);
        let po = Self::mean_of(&self.p_off);
        let ys = self.y_star(x);
        let m = S::from_usize(self.m);
        (0..self.dim)
            .map(|k| {
                let resid_mean = ys[k] - pd[k] * x[k] - po[k];
                let m2_pd = self
                    .p_diag
                    .iter()
                    .map(|p| p[k] * p[k])
                    .fold(S::ZERO, |acc, v| acc + v)
                    / m;
                let m_pd_po = self
                    .p_diag
                    .iter()
                    .zip(&self.p_off)
                    .map(|(p, o)| p[k] * o[k])
                    .fold(S::ZERO, |acc, v| acc + v)
                    / m;
                let gxf_mean = -(pd[k] * ys[k] - m2_pd * x[k] - m_pd_po);
                (qd[k] / a[k]) * resid_mean + gxf_mean
            })
            .collect()
    }

    /// ψ(x) = f̄(x, y*(x)) evaluated exactly (per-node residuals).
    pub fn psi(&self, x: &[S]) -> f64 {
        let ys = self.y_star(x);
        let mut acc = 0.0;
        for i in 0..self.m {
            for k in 0..self.dim {
                let r = ys[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k];
                acc += 0.5 * r.to_f64().powi(2);
            }
        }
        acc / self.m as f64
    }
}

impl<S: Scalar> BilevelTask<S> for QuadraticTask<S> {
    fn nodes(&self) -> usize {
        self.m
    }

    fn dx(&self) -> usize {
        self.dim
    }

    fn dy(&self) -> usize {
        self.dim
    }

    fn name(&self) -> String {
        format!("quadratic(m={}, d={})", self.m, self.dim)
    }

    fn inner_y_grad(&self, i: usize, x: &[S], y: &[S], lambda: S) -> Result<Vec<S>> {
        // ∇_y h = ∇_y f + λ ∇_y g
        Ok((0..self.dim)
            .map(|k| {
                let gyf = y[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k];
                let gyg = self.a[i][k] * y[k] - (self.q_diag[i][k] * x[k] + self.q_off[i][k]);
                gyf + lambda * gyg
            })
            .collect())
    }

    fn inner_z_grad(&self, i: usize, x: &[S], z: &[S]) -> Result<Vec<S>> {
        Ok((0..self.dim)
            .map(|k| self.a[i][k] * z[k] - (self.q_diag[i][k] * x[k] + self.q_off[i][k]))
            .collect())
    }

    fn hypergrad(&self, i: usize, x: &[S], y: &[S], z: &[S], lambda: S) -> Result<Vec<S>> {
        // ∇_x f_i −... fully first-order form:
        // u = ∇_x f_i(x,y) + λ(∇_x g_i(x,y) − ∇_x g_i(x,z))
        // ∇_x f_i = −P_i (y − P_i x − p_i);  ∇_x g_i(x,·) = −Q_i ·
        Ok((0..self.dim)
            .map(|k| {
                let gxf = -self.p_diag[i][k] * (y[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k]);
                let gxg_y = -self.q_diag[i][k] * y[k];
                let gxg_z = -self.q_diag[i][k] * z[k];
                gxf + lambda * (gxg_y - gxg_z)
            })
            .collect())
    }

    fn eval(&self, i: usize, x: &[S], y: &[S]) -> Result<(f64, f64)> {
        let loss: f64 = (0..self.dim)
            .map(|k| {
                0.5 * (y[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k]).to_f64().powi(2)
            })
            .sum();
        // "Accuracy" proxy for a regression task: 1/(1+loss) ∈ (0,1].
        Ok((loss, 1.0 / (1.0 + loss)))
    }

    fn grad_y_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>> {
        Ok((0..self.dim)
            .map(|k| y[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k])
            .collect())
    }

    fn grad_x_f(&self, i: usize, x: &[S], y: &[S]) -> Result<Vec<S>> {
        Ok((0..self.dim)
            .map(|k| -self.p_diag[i][k] * (y[k] - self.p_diag[i][k] * x[k] - self.p_off[i][k]))
            .collect())
    }

    fn hvp_yy_g(&self, i: usize, _x: &[S], _y: &[S], v: &[S]) -> Result<Vec<S>> {
        Ok((0..self.dim).map(|k| self.a[i][k] * v[k]).collect())
    }

    fn jvp_xy_g(&self, i: usize, _x: &[S], _y: &[S], v: &[S]) -> Result<Vec<S>> {
        // ∂²g/∂x∂y = −Q_i (diagonal) ⇒ (∇²_xy g)·v = −Q_i v
        Ok((0..self.dim).map(|k| -self.q_diag[i][k] * v[k]).collect())
    }

    fn init_x(&self, rng: &mut Rng) -> Vec<S> {
        // f32 draw, exact widening: the same x₀ at every dtype.
        (0..self.dim)
            .map(|_| S::from_f64(rng.normal_f32(0.0, 0.5) as f64))
            .collect()
    }

    fn init_y(&self, _rng: &mut Rng) -> Vec<S> {
        vec![S::ZERO; self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn y_star_zeroes_mean_ll_gradient() {
        let t: QuadraticTask = QuadraticTask::generate(5, 6, 1.0, 1);
        let mut rng = Rng::new(2);
        let x = t.init_x(&mut rng);
        let ys = t.y_star(&x);
        let mut mean_grad = vec![0.0f64; 6];
        for i in 0..5 {
            let g = t.inner_z_grad(i, &x, &ys).unwrap();
            for k in 0..6 {
                mean_grad[k] += g[k] as f64 / 5.0;
            }
        }
        for g in mean_grad {
            assert!(g.abs() < 1e-5, "{g}");
        }
    }

    #[test]
    fn penalty_hypergrad_approaches_analytic_as_lambda_grows() {
        // Kwon-style bound: ‖∇ψ_λ − ∇ψ‖ = O(1/λ).  Evaluate the penalty
        // hypergradient at the EXACT minimizers y*_λ(x), y*(x) and compare.
        let t: QuadraticTask = QuadraticTask::generate(4, 5, 0.8, 3);
        let mut rng = Rng::new(4);
        let x = t.init_x(&mut rng);
        let analytic = t.hypergrad_analytic(&x);

        let err_for = |lambda: f32| -> f64 {
            // y*_λ minimizes f̄ + λḡ: coordinate-wise
            // (1 + λā) y = P̄x + p̄ + λ(Q̄x + q̄)
            let a = QuadraticTask::mean_of(&t.a);
            let qd = QuadraticTask::mean_of(&t.q_diag);
            let qo = QuadraticTask::mean_of(&t.q_off);
            let pd = QuadraticTask::mean_of(&t.p_diag);
            let po = QuadraticTask::mean_of(&t.p_off);
            let y_lam: Vec<f32> = (0..t.dim)
                .map(|k| {
                    (pd[k] * x[k] + po[k] + lambda * (qd[k] * x[k] + qo[k]))
                        / (1.0 + lambda * a[k])
                })
                .collect();
            let z = t.y_star(&x);
            let mut u_mean = vec![0.0f64; t.dim];
            for i in 0..t.m {
                let u = t.hypergrad(i, &x, &y_lam, &z, lambda).unwrap();
                for k in 0..t.dim {
                    u_mean[k] += u[k] as f64 / t.m as f64;
                }
            }
            u_mean
                .iter()
                .zip(&analytic)
                .map(|(a, b)| (a - *b as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };

        let e10 = err_for(10.0);
        let e100 = err_for(100.0);
        let e1000 = err_for(1000.0);
        assert!(e100 < e10 / 5.0, "{e100} !< {e10}/5");
        assert!(e1000 < e100 / 5.0, "{e1000} !< {e100}/5");
    }

    #[test]
    fn analytic_hypergrad_matches_finite_difference_of_psi() {
        let t: QuadraticTask = QuadraticTask::generate(5, 4, 1.0, 11);
        let mut rng = Rng::new(12);
        let x = t.init_x(&mut rng);
        let g = t.hypergrad_analytic(&x);
        let eps = 1e-3f32;
        for k in 0..4 {
            let mut xp = x.clone();
            xp[k] += eps;
            let mut xm = x.clone();
            xm[k] -= eps;
            let fd = (t.psi(&xp) - t.psi(&xm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[k] as f64).abs() < 1e-2 * (1.0 + fd.abs()),
                "coord {k}: fd {fd} vs analytic {}",
                g[k]
            );
        }
    }

    #[test]
    fn hvp_and_jvp_match_finite_differences() {
        let t: QuadraticTask = QuadraticTask::generate(3, 4, 1.0, 5);
        let mut rng = Rng::new(6);
        let x = t.init_x(&mut rng);
        let y = t.init_x(&mut rng);
        let v: Vec<f32> = (0..4).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let eps = 1e-3f32;
        // (∇_y g(y + εv) − ∇_y g(y)) / ε ≈ H v
        let y2: Vec<f32> = y.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let g1 = t.inner_z_grad(0, &x, &y).unwrap();
        let g2 = t.inner_z_grad(0, &x, &y2).unwrap();
        let hv = t.hvp_yy_g(0, &x, &y, &v).unwrap();
        for k in 0..4 {
            let fd = (g2[k] - g1[k]) / eps;
            assert!((fd - hv[k]).abs() < 1e-2, "{fd} vs {}", hv[k]);
        }
        // cross: (∇_y g(x + εv_x) − ∇_y g(x)) / ε ≈ (∇²_yx g) v_x; our
        // jvp_xy is the transpose contraction — diagonal, so symmetric.
        let x2: Vec<f32> = x.iter().zip(&v).map(|(a, b)| a + eps * b).collect();
        let g3 = t.inner_z_grad(0, &x2, &y).unwrap();
        let jv = t.jvp_xy_g(0, &x, &y, &v).unwrap();
        for k in 0..4 {
            let fd = (g3[k] - g1[k]) / eps;
            assert!((fd - jv[k]).abs() < 1e-2, "{fd} vs {}", jv[k]);
        }
    }

    /// The f64 instance is the exact widening of the f32 instance (same
    /// RNG stream, lossless casts), and its oracles agree with the f32
    /// ones to well within f32 rounding.
    #[test]
    fn f64_instance_widens_f32_instance_exactly() {
        let t32: QuadraticTask = QuadraticTask::generate(4, 6, 1.0, 77);
        let t64: QuadraticTask<f64> = QuadraticTask::generate(4, 6, 1.0, 77);
        for i in 0..4 {
            for k in 0..6 {
                assert_eq!(t32.a[i][k] as f64, t64.a[i][k]);
                assert_eq!(t32.q_diag[i][k] as f64, t64.q_diag[i][k]);
                assert_eq!(t32.p_off[i][k] as f64, t64.p_off[i][k]);
            }
        }
        let mut r32 = Rng::new(5);
        let mut r64 = Rng::new(5);
        let x32 = t32.init_x(&mut r32);
        let x64 = t64.init_x(&mut r64);
        let y32 = t32.y_star(&x32);
        let y64 = t64.y_star(&x64);
        for k in 0..6 {
            assert_eq!(x32[k] as f64, x64[k], "same x₀ at both dtypes");
            let rel = (y32[k] as f64 - y64[k]).abs() / (1.0 + y64[k].abs());
            assert!(rel < 1e-6, "coord {k}: f32 {} vs f64 {}", y32[k], y64[k]);
        }
    }
}
