//! [`PjrtTask`]: the real oracle bundle, backed by the AOT artifacts.
//!
//! Construction loads the preset's eight oracles from the manifest,
//! generates/partitions the synthetic corpus to the artifact's static
//! per-node shapes, and stages each node's data shard as device buffers
//! once — the hot path then only uploads parameter vectors.

use super::BilevelTask;
use crate::data::{mnist_like, newsgroups_like, partition::Partition};
use crate::runtime::{Arg, ArtifactRegistry, Oracle, Staged};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::rc::Rc;

/// Which argument convention the preset's entry points use (they differ
/// because ∇_x f ≡ 0 for the coefficient-tuning task).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Style {
    Coeff,
    HyperRep,
}

struct NodeData {
    atr: Staged,
    btr: Staged,
    aval: Staged,
    bval: Staged,
}

pub struct PjrtTask {
    preset: String,
    style: Style,
    m: usize,
    dx: usize,
    dy: usize,
    inner_y: Rc<Oracle>,
    inner_z: Rc<Oracle>,
    hyper: Rc<Oracle>,
    eval: Rc<Oracle>,
    hvp_yy: Rc<Oracle>,
    jvp_xy: Rc<Oracle>,
    gyf: Rc<Oracle>,
    gxf: Rc<Oracle>,
    nodes: Vec<NodeData>,
    /// For hyperrep: backbone layer dims for init; for coeff unused.
    init_dims: Vec<usize>,
}

impl PjrtTask {
    /// Build a task over `m` nodes from a preset ("coeff", "hyperrep",
    /// "coeff_tiny", ..., or their `_jnp` variants), generating a fresh
    /// synthetic corpus and partitioning it with `partition`.
    pub fn build(
        reg: &ArtifactRegistry,
        preset: &str,
        m: usize,
        partition: Partition,
        data_noise: f32,
        seed: u64,
    ) -> Result<PjrtTask> {
        if !reg.has_preset(preset) {
            bail!(
                "preset {preset:?} not in artifacts manifest — run `make artifacts`"
            );
        }
        let style = if preset.starts_with("coeff") {
            Style::Coeff
        } else if preset.starts_with("hyperrep") {
            Style::HyperRep
        } else {
            bail!("preset {preset:?} is not a bilevel task preset");
        };
        let dx = reg.preset_dim(preset, "dx")?;
        let dy = reg.preset_dim(preset, "dy")?;
        let classes = reg.preset_dim(preset, "classes")?;
        let n_train = reg.preset_dim(preset, "n_train")?;
        let n_val = reg.preset_dim(preset, "n_val")?;

        let mut rng = Rng::new(seed);
        // Generate a global pool about 1.5× the total need, partition the
        // train side across nodes, then resize each shard to the static
        // artifact shapes.
        let need_tr = m * n_train;
        let need_val = m * n_val;
        let global = match style {
            Style::Coeff => {
                let features = reg.preset_dim(preset, "features")?;
                newsgroups_like(
                    (need_tr + need_val) * 3 / 2,
                    features,
                    classes,
                    data_noise,
                    rng.next_u64(),
                )
            }
            Style::HyperRep => {
                let inputs = reg.preset_dim(preset, "inputs")?;
                mnist_like(
                    (need_tr + need_val) * 3 / 2,
                    inputs,
                    classes,
                    data_noise,
                    rng.next_u64(),
                )
            }
        };
        let (train_pool, val_pool) = global.split(
            need_tr as f64 / (need_tr + need_val) as f64,
            &mut rng,
        );
        // Heterogeneity applies to the training shards (the paper's
        // protocol); validation is split IID so the eval metric is
        // comparable across nodes.
        let train_shards = partition.split(&train_pool, m, &mut rng);
        let val_shards = Partition::Iid.split(&val_pool, m, &mut rng);

        let e = |name: &str| reg.load(&format!("{preset}.{name}"));
        let inner_y = e("inner_y")?;
        let inner_z = e("inner_z")?;
        let hyper = e("hyper")?;
        let eval = e("eval")?;
        let hvp_yy = e("hvp_yy_g")?;
        let jvp_xy = e("jvp_xy_g")?;
        let gyf = e("grad_y_f")?;
        let gxf = e("grad_x_f")?;

        let feat_dim = match style {
            Style::Coeff => reg.preset_dim(preset, "features")?,
            Style::HyperRep => reg.preset_dim(preset, "inputs")?,
        };
        let mut nodes = Vec::with_capacity(m);
        for i in 0..m {
            let tr = train_shards[i].resize_to(n_train, &mut rng);
            let va = val_shards[i].resize_to(n_val, &mut rng);
            if tr.d != feat_dim {
                bail!("data dim {} != artifact feature dim {}", tr.d, feat_dim);
            }
            nodes.push(NodeData {
                atr: inner_y.stage(&tr.features, &[n_train, feat_dim])?,
                btr: inner_y.stage(&tr.onehot(), &[n_train, classes])?,
                aval: inner_y.stage(&va.features, &[n_val, feat_dim])?,
                bval: inner_y.stage(&va.onehot(), &[n_val, classes])?,
            });
        }

        let init_dims = match style {
            Style::HyperRep => vec![
                reg.preset_dim(preset, "inputs")?,
                reg.preset_dim(preset, "hidden1")?,
                reg.preset_dim(preset, "hidden2")?,
                classes,
            ],
            Style::Coeff => vec![],
        };

        Ok(PjrtTask {
            preset: preset.to_string(),
            style,
            m,
            dx,
            dy,
            inner_y,
            inner_z,
            hyper,
            eval,
            hvp_yy,
            jvp_xy,
            gyf,
            gxf,
            nodes,
            init_dims,
        })
    }

    /// Build with per-node datasets supplied by the caller (used by tests
    /// exercising specific data distributions).
    pub fn per_node_datasets(&self) -> usize {
        self.nodes.len()
    }

    fn single(&self, o: &Oracle, args: &[Arg]) -> Result<Vec<f32>> {
        let mut outs = o.call(args)?;
        if outs.len() != 1 {
            bail!("{}: expected 1 output, got {}", o.name, outs.len());
        }
        Ok(outs.remove(0))
    }
}

impl BilevelTask for PjrtTask {
    fn nodes(&self) -> usize {
        self.m
    }

    fn dx(&self) -> usize {
        self.dx
    }

    fn dy(&self) -> usize {
        self.dy
    }

    fn name(&self) -> String {
        format!("pjrt:{}", self.preset)
    }

    fn inner_y_grad(&self, i: usize, x: &[f32], y: &[f32], lambda: f32) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        // Both styles: (x, y, lam, atr, btr, aval, bval).
        self.single(
            &self.inner_y,
            &[
                Arg::Host(x),
                Arg::Host(y),
                Arg::Scalar(lambda),
                Arg::Staged(&n.atr),
                Arg::Staged(&n.btr),
                Arg::Staged(&n.aval),
                Arg::Staged(&n.bval),
            ],
        )
    }

    fn inner_z_grad(&self, i: usize, x: &[f32], z: &[f32]) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        self.single(
            &self.inner_z,
            &[Arg::Host(x), Arg::Host(z), Arg::Staged(&n.atr), Arg::Staged(&n.btr)],
        )
    }

    fn hypergrad(&self, i: usize, x: &[f32], y: &[f32], z: &[f32], lambda: f32) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        match self.style {
            Style::Coeff => self.single(
                &self.hyper,
                &[Arg::Host(x), Arg::Host(y), Arg::Host(z), Arg::Scalar(lambda)],
            ),
            Style::HyperRep => self.single(
                &self.hyper,
                &[
                    Arg::Host(x),
                    Arg::Host(y),
                    Arg::Host(z),
                    Arg::Scalar(lambda),
                    Arg::Staged(&n.atr),
                    Arg::Staged(&n.btr),
                    Arg::Staged(&n.aval),
                    Arg::Staged(&n.bval),
                ],
            ),
        }
    }

    fn eval(&self, i: usize, x: &[f32], y: &[f32]) -> Result<(f64, f64)> {
        let n = &self.nodes[i];
        let outs = match self.style {
            Style::Coeff => self.eval.call(&[
                Arg::Host(y),
                Arg::Staged(&n.aval),
                Arg::Staged(&n.bval),
            ])?,
            Style::HyperRep => self.eval.call(&[
                Arg::Host(x),
                Arg::Host(y),
                Arg::Staged(&n.aval),
                Arg::Staged(&n.bval),
            ])?,
        };
        if outs.len() != 2 {
            bail!("eval: expected (loss, acc), got {} outputs", outs.len());
        }
        let loss = *outs[0].first().ok_or_else(|| anyhow!("empty loss"))? as f64;
        let acc = *outs[1].first().ok_or_else(|| anyhow!("empty acc"))? as f64;
        Ok((loss, acc))
    }

    fn grad_y_f(&self, i: usize, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        match self.style {
            Style::Coeff => self.single(
                &self.gyf,
                &[Arg::Host(y), Arg::Staged(&n.aval), Arg::Staged(&n.bval)],
            ),
            Style::HyperRep => self.single(
                &self.gyf,
                &[
                    Arg::Host(x),
                    Arg::Host(y),
                    Arg::Staged(&n.aval),
                    Arg::Staged(&n.bval),
                ],
            ),
        }
    }

    fn grad_x_f(&self, i: usize, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        match self.style {
            Style::Coeff => self.single(&self.gxf, &[Arg::Host(x), Arg::Host(y)]),
            Style::HyperRep => self.single(
                &self.gxf,
                &[
                    Arg::Host(x),
                    Arg::Host(y),
                    Arg::Staged(&n.aval),
                    Arg::Staged(&n.bval),
                ],
            ),
        }
    }

    fn hvp_yy_g(&self, i: usize, x: &[f32], y: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        self.single(
            &self.hvp_yy,
            &[
                Arg::Host(x),
                Arg::Host(y),
                Arg::Host(v),
                Arg::Staged(&n.atr),
                Arg::Staged(&n.btr),
            ],
        )
    }

    fn jvp_xy_g(&self, i: usize, x: &[f32], y: &[f32], v: &[f32]) -> Result<Vec<f32>> {
        let n = &self.nodes[i];
        match self.style {
            Style::Coeff => self.single(
                &self.jvp_xy,
                &[Arg::Host(x), Arg::Host(y), Arg::Host(v)],
            ),
            Style::HyperRep => self.single(
                &self.jvp_xy,
                &[
                    Arg::Host(x),
                    Arg::Host(y),
                    Arg::Host(v),
                    Arg::Staged(&n.atr),
                    Arg::Staged(&n.btr),
                ],
            ),
        }
    }

    fn init_x(&self, rng: &mut Rng) -> Vec<f32> {
        match self.style {
            // log-regularizer weights start at 0 (reg weight exp(0) = 1).
            Style::Coeff => vec![0.0; self.dx],
            Style::HyperRep => {
                // He-style init per backbone layer.
                let (i, h1, h2) = (self.init_dims[0], self.init_dims[1], self.init_dims[2]);
                let mut x = Vec::with_capacity(self.dx);
                let mut layer = |fan_in: usize, rows: usize, cols: usize, x: &mut Vec<f32>| {
                    let std = (2.0 / fan_in as f32).sqrt();
                    for _ in 0..rows * cols {
                        x.push(rng.normal_f32(0.0, std));
                    }
                    for _ in 0..cols {
                        x.push(0.0); // bias
                    }
                };
                layer(i, i, h1, &mut x);
                layer(h1, h1, h2, &mut x);
                debug_assert_eq!(x.len(), self.dx);
                x
            }
        }
    }

    fn init_y(&self, _rng: &mut Rng) -> Vec<f32> {
        vec![0.0; self.dy]
    }
}
