//! Experiment configuration: typed config struct, TOML loading, CLI
//! overrides, and the defaults from the paper's §6 / Appendix C.

pub mod toml;

use crate::data::partition::Partition;
use crate::linalg::Dtype;
use crate::metrics::StopCondition;
use crate::sim::{NetConfig, NetMode};
use crate::topology::Topology;
use std::collections::BTreeMap;
use std::path::Path;
use toml::TomlValue;

/// Which algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// The paper's method (Algorithm 1 + 2).
    C2dfb,
    /// Ablation: naive compression with local error feedback, no reference
    /// points (the paper's C²DFB(nc)).
    C2dfbNc,
    /// MA-DSBO-style second-order baseline (moving average + HVP solver).
    Madsbo,
    /// Gossip bilevel with Neumann-series hypergradient (MDBO).
    Mdbo,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::C2dfb => "c2dfb",
            Algorithm::C2dfbNc => "c2dfb_nc",
            Algorithm::Madsbo => "madsbo",
            Algorithm::Mdbo => "mdbo",
        }
    }

    pub fn parse(s: &str) -> Result<Algorithm, String> {
        match s {
            "c2dfb" => Ok(Algorithm::C2dfb),
            "c2dfb_nc" | "c2dfb-nc" | "nc" => Ok(Algorithm::C2dfbNc),
            "madsbo" => Ok(Algorithm::Madsbo),
            "mdbo" => Ok(Algorithm::Mdbo),
            _ => Err(format!("unknown algorithm: {s}")),
        }
    }
}

/// The `[obs]` config table: which telemetry sinks ([`crate::obs`]) a run
/// attaches.  Off by default — with both sinks off the recorder is a
/// no-op and the hot paths stay allocation-free.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsConfig {
    /// Write the deterministic JSONL trace to this path (CLI: --trace).
    pub trace: Option<String>,
    /// Collect the wall-clock phase profile (CLI: --profile; explicitly
    /// nondeterministic, reported separately from the trace).
    pub profile: bool,
}

/// The `[stop]` config table: optional budgets the runner turns into
/// [`StopCondition`]s on top of the always-present `rounds` cap and the
/// optional `target_accuracy`.  `None` everywhere (the default) keeps the
/// classic fixed-round behaviour.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StopConfig {
    /// Communication budget in MB ([`StopCondition::CommBudgetMb`]).
    pub comm_mb: Option<f64>,
    /// First-order oracle budget ([`StopCondition::FirstOrderOracles`]).
    pub first_order: Option<u64>,
    /// Wall-clock limit, seconds ([`StopCondition::WallClockSecs`]).
    pub wall_secs: Option<f64>,
    /// Virtual network-time limit, seconds ([`StopCondition::SimTimeSecs`]).
    pub sim_secs: Option<f64>,
}

/// The `[sampling]` config table: per-round node sampling.  Each outer
/// round draws a Bernoulli active mask (a pure function of the seed and
/// round index); inactive nodes freeze — no oracle calls, no transmitted
/// bytes — while active nodes keep the reference-point invariant alive by
/// construction.  Only C²DFB / C²DFB(nc) support rates below 1.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingConfig {
    /// Fraction of nodes active per round, in (0, 1].  The default 1.0
    /// disables sampling entirely (bit-identical to the unsampled path;
    /// no RNG is consumed).
    pub rate: f64,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { rate: 1.0 }
    }
}

/// The `[scale]` config table: large-m machinery.
#[derive(Clone, Debug, PartialEq)]
pub struct ScaleConfig {
    /// Answer topology queries from a generator ([`crate::collective::GenNetwork`],
    /// O(m·degree) memory) instead of materializing the graph and mixing
    /// matrix (O(m²)).  Requires a generator-capable topology (ring,
    /// exponential, torus, rreg), the synchronous engine, and no topology
    /// schedule.  Bit-identical to the materialized path.
    pub generator: bool,
    /// Consensus-distance estimator: "auto" (exact below 4096 nodes,
    /// strided above), "auto:THRESHOLD", "exact", or "strided:K".
    pub consensus: String,
}

impl Default for ScaleConfig {
    fn default() -> Self {
        ScaleConfig { generator: false, consensus: "auto".into() }
    }
}

/// Full experiment description.  Defaults reproduce the paper's
/// coefficient-tuning setting (Appendix C.1): η_in = η_out = 1,
/// mixing step 0.5, λ = 10, K = 15, top-k 20%, m = 10, ring.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    /// Artifact preset: "coeff", "coeff_tiny", "hyperrep", ... (see
    /// python/compile/model.py).
    pub preset: String,
    pub algorithm: Algorithm,
    pub nodes: usize,
    pub topology: Topology,
    pub partition: Partition,
    /// Compressor spec for the inner loop, e.g. "topk:0.2".
    pub compressor: String,
    /// Payload scalar for iterates, oracles, and wire payloads: "f32"
    /// (the default, byte-identical to the historical path) or "f64"
    /// (native tasks only; see docs/DTYPE.md).
    pub dtype: Dtype,

    pub rounds: usize,
    pub inner_steps: usize, // K
    pub eta_out: f64,
    pub eta_in: f64,
    pub gamma_out: f64, // outer mixing step
    pub gamma_in: f64,  // inner mixing step
    pub lambda: f64,    // penalty multiplier (the paper's λ / σ)

    pub seed: u64,
    pub eval_every: usize,
    /// Stop early once this test accuracy is reached (None = run all rounds).
    pub target_accuracy: Option<f64>,
    /// Samples per node are set by the artifact shapes; this scales the
    /// globally generated pool before partitioning.
    pub data_noise: f64,
    pub out_dir: String,
    /// The `[network]` table: transport engine, link model, fault
    /// injection, and the per-node compute thread pool.
    pub network: NetConfig,
    /// The `[stop]` table: budgeted stopping conditions beyond the round
    /// cap (communication, oracles, wall/sim time).
    pub stop: StopConfig,
    /// The `[obs]` table: telemetry sinks (JSONL trace, phase profiler).
    pub obs: ObsConfig,
    /// The `[sampling]` table: per-round node sampling.
    pub sampling: SamplingConfig,
    /// The `[scale]` table: generator transport + consensus estimator.
    pub scale: ScaleConfig,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "experiment".into(),
            preset: "coeff".into(),
            algorithm: Algorithm::C2dfb,
            nodes: 10,
            topology: Topology::Ring,
            partition: Partition::Iid,
            compressor: "topk:0.2".into(),
            dtype: Dtype::F32,
            rounds: 200,
            inner_steps: 15,
            eta_out: 1.0,
            eta_in: 1.0,
            gamma_out: 0.5,
            gamma_in: 0.5,
            lambda: 10.0,
            seed: 42,
            eval_every: 5,
            target_accuracy: None,
            data_noise: 0.35,
            out_dir: "runs".into(),
            network: NetConfig::default(),
            stop: StopConfig::default(),
            obs: ObsConfig::default(),
            sampling: SamplingConfig::default(),
            scale: ScaleConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Paper defaults for the hyper-representation task (Appendix C.2):
    /// inner lr 1, outer lr 0.8, mixing 0.3, λ = 10, ~30% compression.
    pub fn hyperrep_defaults() -> ExperimentConfig {
        ExperimentConfig {
            name: "hyperrep".into(),
            preset: "hyperrep".into(),
            compressor: "topk:0.3".into(),
            eta_out: 0.8,
            eta_in: 1.0,
            gamma_out: 0.3,
            gamma_in: 0.3,
            inner_steps: 10,
            lambda: 10.0,
            data_noise: 0.15,
            ..ExperimentConfig::default()
        }
    }

    pub fn label(&self) -> String {
        // The default dtype stays out of the label so every pre-dtype run
        // name (goldens, sweep caches) is unchanged.
        let dtype = match self.dtype {
            Dtype::F32 => "",
            Dtype::F64 => "_f64",
        };
        format!(
            "{}_{}_{}_m{}{}",
            self.preset,
            self.topology.name(),
            self.partition.name().replace(':', ""),
            self.nodes,
            dtype
        )
    }

    /// Load from a TOML file; keys may be bare or under [experiment].
    pub fn from_toml_file(path: &Path) -> Result<ExperimentConfig, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let map = toml::parse(&text)?;
        let mut cfg = ExperimentConfig::default();
        cfg.apply_map(&map)?;
        Ok(cfg)
    }

    /// Apply flattened key→value overrides (used by both TOML and CLI).
    /// `seed` is applied first regardless of map order: `topology` and
    /// `network.topology_schedule` freeze a seed-dependent realization
    /// when parsed.
    pub fn apply_map(&mut self, map: &BTreeMap<String, TomlValue>) -> Result<(), String> {
        for pass in 0..2 {
            for (key, v) in map {
                let k = key.strip_prefix("experiment.").unwrap_or(key);
                if (k == "seed") == (pass == 0) {
                    self.apply_one(k, v)?;
                }
            }
        }
        Ok(())
    }

    pub fn apply_one(&mut self, k: &str, v: &TomlValue) -> Result<(), String> {
        let want_str = || v.as_str().map(str::to_string).ok_or(format!("{k}: expected string"));
        let want_f64 = || v.as_f64().ok_or(format!("{k}: expected number"));
        let want_usize = || {
            v.as_i64()
                .filter(|i| *i >= 0)
                .map(|i| i as usize)
                .ok_or(format!("{k}: expected non-negative integer"))
        };
        match k {
            "name" => self.name = want_str()?,
            "preset" | "task" => self.preset = want_str()?,
            "algorithm" | "algo" => self.algorithm = Algorithm::parse(&want_str()?)?,
            "nodes" | "m" => self.nodes = want_usize()?,
            "topology" => self.topology = Topology::parse(&want_str()?, self.seed)?,
            "partition" => self.partition = Partition::parse(&want_str()?)?,
            "compressor" => self.compressor = want_str()?,
            "dtype" => self.dtype = Dtype::parse(&want_str()?)?,
            "rounds" => self.rounds = want_usize()?,
            "inner_steps" | "K" | "k" => self.inner_steps = want_usize()?,
            "eta_out" => self.eta_out = want_f64()?,
            "eta_in" => self.eta_in = want_f64()?,
            "gamma_out" => self.gamma_out = want_f64()?,
            "gamma_in" => self.gamma_in = want_f64()?,
            "gamma" => {
                self.gamma_out = want_f64()?;
                self.gamma_in = self.gamma_out;
            }
            "lambda" | "sigma" => self.lambda = want_f64()?,
            "seed" => self.seed = want_usize()? as u64,
            "eval_every" => self.eval_every = want_usize()?.max(1),
            "target_accuracy" => self.target_accuracy = Some(want_f64()?),
            "data_noise" => self.data_noise = want_f64()?,
            "out_dir" => self.out_dir = want_str()?,
            // --- the [network] table (TOML: network.*; CLI: bare keys) ---
            "network" | "network.mode" => {
                self.network.mode = NetMode::parse(&want_str()?)?
            }
            "network.latency" | "latency" => self.network.latency_s = want_f64()?,
            "network.jitter" | "jitter" => self.network.jitter_s = want_f64()?,
            "network.bandwidth" | "bandwidth" => {
                self.network.bandwidth_bytes_per_s = want_f64()?
            }
            "network.drop_rate" | "drop_rate" => self.network.drop_rate = want_f64()?,
            "network.straggler" | "straggler" => {
                self.network.parse_straggler(&want_str()?)?
            }
            "network.topology_schedule" | "topology_schedule" => {
                let spec = want_str()?;
                self.network.parse_schedule(&spec, self.seed)?
            }
            "network.threads" | "threads" => self.network.threads = want_usize()?,
            // --- the [stop] table (TOML: stop.*; CLI: --stop_* flags) ---
            "stop.rounds" | "stop_rounds" => self.rounds = want_usize()?,
            "stop.target_accuracy" | "stop_target_accuracy" => {
                self.target_accuracy = Some(want_f64()?)
            }
            "stop.comm_mb" | "stop_comm_mb" => self.stop.comm_mb = Some(want_f64()?),
            "stop.first_order" | "stop_first_order" => {
                self.stop.first_order = Some(
                    v.as_u64()
                        .ok_or(format!("{k}: expected non-negative integer"))?,
                )
            }
            "stop.wall_secs" | "stop_wall_secs" => self.stop.wall_secs = Some(want_f64()?),
            "stop.sim_secs" | "stop_sim_secs" => self.stop.sim_secs = Some(want_f64()?),
            // --- the [obs] table (TOML: obs.*; CLI: --trace/--profile) ---
            "obs.trace" | "trace" => self.obs.trace = Some(want_str()?),
            "obs.profile" | "profile" => {
                self.obs.profile = v.as_bool().ok_or(format!("{k}: expected bool"))?
            }
            // --- the [sampling] table ------------------------------------
            "sampling.rate" | "sample_rate" => self.sampling.rate = want_f64()?,
            // --- the [scale] table ---------------------------------------
            "scale.generator" | "generator" => {
                self.scale.generator = v.as_bool().ok_or(format!("{k}: expected bool"))?
            }
            "scale.consensus" | "consensus_estimator" => {
                self.scale.consensus = want_str()?
            }
            _ => return Err(format!("unknown config key: {k}")),
        }
        Ok(())
    }

    /// The stop-condition set the runner evaluates at every eval point.
    /// Budget/target conditions come first so their reason wins when a
    /// budget and the round cap fire at the same evaluation; the `rounds`
    /// cap is always present and always last.
    pub fn stop_conditions(&self) -> Vec<StopCondition> {
        let mut v = Vec::new();
        if let Some(a) = self.target_accuracy {
            v.push(StopCondition::TargetAccuracy(a));
        }
        if let Some(mb) = self.stop.comm_mb {
            v.push(StopCondition::CommBudgetMb(mb));
        }
        if let Some(n) = self.stop.first_order {
            v.push(StopCondition::FirstOrderOracles(n));
        }
        if let Some(s) = self.stop.sim_secs {
            v.push(StopCondition::SimTimeSecs(s));
        }
        if let Some(s) = self.stop.wall_secs {
            v.push(StopCondition::WallClockSecs(s));
        }
        v.push(StopCondition::Rounds(self.rounds));
        v
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.nodes < 2 {
            anyhow::bail!("need at least 2 nodes");
        }
        if !(0.0..=1.0).contains(&self.gamma_in) || !(0.0..=1.0).contains(&self.gamma_out) {
            anyhow::bail!("mixing steps must lie in [0, 1]");
        }
        if self.lambda <= 0.0 {
            anyhow::bail!("lambda must be positive");
        }
        if self.inner_steps == 0 {
            anyhow::bail!("inner_steps must be >= 1");
        }
        // Compressor specs are dtype-independent; validating at f32 covers
        // both payload widths.
        crate::compress::parse::<f32>(&self.compressor).map_err(anyhow::Error::msg)?;
        self.network.validate().map_err(anyhow::Error::msg)?;
        for (key, val) in [
            ("stop.comm_mb", self.stop.comm_mb),
            ("stop.wall_secs", self.stop.wall_secs),
            ("stop.sim_secs", self.stop.sim_secs),
        ] {
            if let Some(x) = val {
                if x.is_nan() || x <= 0.0 {
                    anyhow::bail!("{key} must be positive, got {x}");
                }
            }
        }
        if self.stop.first_order == Some(0) {
            anyhow::bail!("stop.first_order must be positive");
        }
        if !(self.sampling.rate > 0.0 && self.sampling.rate <= 1.0) {
            anyhow::bail!(
                "sampling.rate must lie in (0, 1], got {}",
                self.sampling.rate
            );
        }
        if self.sampling.rate < 1.0
            && !matches!(self.algorithm, Algorithm::C2dfb | Algorithm::C2dfbNc)
        {
            anyhow::bail!(
                "sampling.rate < 1 is only supported by c2dfb/c2dfb_nc; {} \
                 has no frozen-node semantics",
                self.algorithm.name()
            );
        }
        crate::metrics::ConsensusEstimator::parse(&self.scale.consensus)
            .map_err(anyhow::Error::msg)?;
        if self.scale.generator {
            if !crate::topology::GenTopology::supports(self.topology) {
                anyhow::bail!(
                    "scale.generator requires a generator-capable topology \
                     (ring, exp, torus, rreg), got {}",
                    self.topology.name()
                );
            }
            if self.network.is_event() {
                anyhow::bail!(
                    "scale.generator runs on the synchronous engine only \
                     (set network.mode = \"sync\")"
                );
            }
            if !self.network.topology_schedule.is_empty() {
                anyhow::bail!("scale.generator does not support a topology schedule");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_appendix() {
        let c = ExperimentConfig::default();
        assert_eq!(c.nodes, 10);
        assert_eq!(c.inner_steps, 15);
        assert_eq!(c.lambda, 10.0);
        assert_eq!(c.gamma_out, 0.5);
        assert_eq!(c.eta_out, 1.0);
        assert_eq!(c.compressor, "topk:0.2");
        assert!(c.validate().is_ok());
    }

    #[test]
    fn toml_roundtrip() {
        let dir = std::env::temp_dir().join("c2dfb_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("cfg.toml");
        std::fs::write(
            &p,
            r#"
[experiment]
name = "t1"
algorithm = "madsbo"
topology = "er:0.4"
partition = "het:0.8"
rounds = 50
lambda = 5.0
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml_file(&p).unwrap();
        assert_eq!(c.name, "t1");
        assert_eq!(c.algorithm, Algorithm::Madsbo);
        assert_eq!(c.topology.name(), "er");
        assert_eq!(c.partition.name(), "het:0.8");
        assert_eq!(c.rounds, 50);
        assert_eq!(c.lambda, 5.0);
        // Untouched keys keep defaults.
        assert_eq!(c.inner_steps, 15);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ExperimentConfig::default();
        let err = c.apply_one("bogus", &TomlValue::Int(1));
        assert!(err.is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.nodes = 1;
        assert!(c.validate().is_err());
        c = ExperimentConfig::default();
        c.gamma_in = 1.5;
        assert!(c.validate().is_err());
        c = ExperimentConfig::default();
        c.compressor = "nonsense".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn algorithm_parse() {
        assert_eq!(Algorithm::parse("c2dfb").unwrap(), Algorithm::C2dfb);
        assert_eq!(Algorithm::parse("nc").unwrap(), Algorithm::C2dfbNc);
        assert!(Algorithm::parse("x").is_err());
    }

    #[test]
    fn network_table_roundtrip() {
        let dir = std::env::temp_dir().join("c2dfb_cfg_net_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("net.toml");
        std::fs::write(
            &p,
            r#"
[experiment]
rounds = 10

[network]
mode = "sim"
latency = 0.05
jitter = 0.01
bandwidth = 12.5e6
drop_rate = 0.1
straggler = "0.2:0.5"
topology_schedule = "0:ring,40:2hop"
threads = 4
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml_file(&p).unwrap();
        assert!(c.network.is_event());
        assert_eq!(c.network.latency_s, 0.05);
        assert_eq!(c.network.jitter_s, 0.01);
        assert_eq!(c.network.bandwidth_bytes_per_s, 12.5e6);
        assert_eq!(c.network.drop_rate, 0.1);
        assert_eq!(c.network.straggler_frac, 0.2);
        assert_eq!(c.network.straggler_delay_s, 0.5);
        assert_eq!(c.network.topology_schedule.len(), 2);
        assert_eq!(c.network.threads, 4);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn seed_applies_before_seeded_keys_regardless_of_map_order() {
        // "network.topology_schedule" < "seed" in BTreeMap order; the
        // schedule's ER realization must still see the configured seed.
        let mut map = BTreeMap::new();
        map.insert("seed".to_string(), TomlValue::Int(7));
        map.insert(
            "network.mode".to_string(),
            TomlValue::Str("sim".into()),
        );
        map.insert(
            "network.topology_schedule".to_string(),
            TomlValue::Str("50:er:0.4".into()),
        );
        let mut c = ExperimentConfig::default();
        c.apply_map(&map).unwrap();
        assert_eq!(c.seed, 7);
        match c.network.topology_schedule[0].1 {
            Topology::ErdosRenyi { seed, .. } => assert_eq!(seed, 7),
            t => panic!("expected ER, got {t:?}"),
        }
    }

    #[test]
    fn cli_style_network_overrides() {
        let mut c = ExperimentConfig::default();
        c.apply_one("network", &TomlValue::Str("sim".into())).unwrap();
        c.apply_one("drop_rate", &TomlValue::Float(0.05)).unwrap();
        c.apply_one("threads", &TomlValue::Int(8)).unwrap();
        assert!(c.network.is_event());
        assert_eq!(c.network.drop_rate, 0.05);
        assert_eq!(c.network.threads, 8);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn stop_table_roundtrip_and_conditions() {
        let dir = std::env::temp_dir().join("c2dfb_cfg_stop_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("stop.toml");
        std::fs::write(
            &p,
            r#"
[experiment]
rounds = 500

[stop]
comm_mb = 12.5
first_order = 100000
wall_secs = 30.0
sim_secs = 2.5
target_accuracy = 0.7
"#,
        )
        .unwrap();
        let c = ExperimentConfig::from_toml_file(&p).unwrap();
        assert_eq!(c.stop.comm_mb, Some(12.5));
        assert_eq!(c.stop.first_order, Some(100_000));
        assert_eq!(c.stop.wall_secs, Some(30.0));
        assert_eq!(c.stop.sim_secs, Some(2.5));
        assert_eq!(c.target_accuracy, Some(0.7));
        assert!(c.validate().is_ok());

        // Condition set: budgets/target first, the round cap always last.
        let conds = c.stop_conditions();
        assert_eq!(conds.len(), 6);
        assert_eq!(conds[0], StopCondition::TargetAccuracy(0.7));
        assert_eq!(*conds.last().unwrap(), StopCondition::Rounds(500));

        // Defaults: only the round cap.
        let d = ExperimentConfig::default();
        assert_eq!(d.stop_conditions(), vec![StopCondition::Rounds(d.rounds)]);
    }

    #[test]
    fn stop_cli_overrides_and_validation() {
        let mut c = ExperimentConfig::default();
        c.apply_one("stop_comm_mb", &TomlValue::Float(4.0)).unwrap();
        c.apply_one("stop_first_order", &TomlValue::Int(5000)).unwrap();
        c.apply_one("stop_rounds", &TomlValue::Int(77)).unwrap();
        assert_eq!(c.stop.comm_mb, Some(4.0));
        assert_eq!(c.stop.first_order, Some(5000));
        assert_eq!(c.rounds, 77);
        assert!(c.validate().is_ok());

        // Budgets must be positive; oracle budgets must be non-negative ints.
        c.stop.comm_mb = Some(0.0);
        assert!(c.validate().is_err());
        c.stop.comm_mb = Some(4.0);
        c.stop.first_order = Some(0);
        assert!(c.validate().is_err(), "a zero oracle budget stops every run at round 0");
        c.stop.first_order = Some(5000);
        assert!(c
            .apply_one("stop_first_order", &TomlValue::Int(-1))
            .is_err());
        assert!(c
            .apply_one("stop_sim_secs", &TomlValue::Str("x".into()))
            .is_err());
    }

    #[test]
    fn obs_table_roundtrip() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.obs, ObsConfig::default());
        c.apply_one("trace", &TomlValue::Str("out.jsonl".into())).unwrap();
        c.apply_one("obs.profile", &TomlValue::Bool(true)).unwrap();
        assert_eq!(c.obs.trace.as_deref(), Some("out.jsonl"));
        assert!(c.obs.profile);
        assert!(c.apply_one("profile", &TomlValue::Int(1)).is_err());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sampling_table_roundtrip_and_validation() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.sampling.rate, 1.0);
        c.apply_one("sampling.rate", &TomlValue::Float(0.25)).unwrap();
        assert_eq!(c.sampling.rate, 0.25);
        assert!(c.validate().is_ok());
        c.apply_one("sample_rate", &TomlValue::Float(0.5)).unwrap();
        assert_eq!(c.sampling.rate, 0.5);

        // Out-of-range rates are rejected.
        for bad in [0.0, -0.1, 1.5, f64::NAN] {
            c.sampling.rate = bad;
            assert!(c.validate().is_err(), "rate {bad} must be rejected");
        }

        // The dense baselines have no frozen-node semantics.
        c.sampling.rate = 0.5;
        c.algorithm = Algorithm::Madsbo;
        assert!(c.validate().is_err(), "madsbo + sampling must be rejected");
        c.sampling.rate = 1.0;
        assert!(c.validate().is_ok(), "madsbo without sampling is fine");
    }

    #[test]
    fn scale_table_roundtrip_and_validation() {
        let mut c = ExperimentConfig::default();
        assert!(!c.scale.generator);
        assert_eq!(c.scale.consensus, "auto");
        c.apply_one("scale.generator", &TomlValue::Bool(true)).unwrap();
        assert!(c.scale.generator);
        assert!(c.validate().is_ok(), "generator on the default ring is fine");

        // Generator-incapable topology.
        c.apply_one("topology", &TomlValue::Str("complete".into())).unwrap();
        assert!(c.validate().is_err());
        c.apply_one("topology", &TomlValue::Str("rreg:4".into())).unwrap();
        assert!(c.validate().is_ok());

        // Event engine and schedules are incompatible with the generator.
        c.apply_one("network", &TomlValue::Str("sim".into())).unwrap();
        assert!(c.validate().is_err());
        c.network = NetConfig::default();
        c.apply_one("topology_schedule", &TomlValue::Str("5:ring".into()))
            .unwrap();
        assert!(c.validate().is_err());

        // Estimator specs parse or are rejected up front.
        let mut c = ExperimentConfig::default();
        c.apply_one("consensus_estimator", &TomlValue::Str("strided:8".into()))
            .unwrap();
        assert!(c.validate().is_ok());
        c.scale.consensus = "bogus".into();
        assert!(c.validate().is_err());
    }

    #[test]
    fn dtype_key_parses_and_labels() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.dtype, Dtype::F32);
        assert!(!c.label().contains("f64"), "default labels must not change");
        c.apply_one("dtype", &TomlValue::Str("f64".into())).unwrap();
        assert_eq!(c.dtype, Dtype::F64);
        assert!(c.label().ends_with("_f64"));
        c.apply_one("dtype", &TomlValue::Str("single".into())).unwrap();
        assert_eq!(c.dtype, Dtype::F32);
        assert!(c.apply_one("dtype", &TomlValue::Str("f16".into())).is_err());
        assert!(c.validate().is_ok());
    }

    #[test]
    fn faults_require_event_engine() {
        let mut c = ExperimentConfig::default();
        c.apply_one("drop_rate", &TomlValue::Float(0.1)).unwrap();
        assert!(c.validate().is_err(), "drops on the sync engine must be rejected");
        c.apply_one("network", &TomlValue::Str("sim".into())).unwrap();
        assert!(c.validate().is_ok());
    }
}
