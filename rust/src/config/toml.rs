//! Minimal TOML-subset parser for experiment config files.
//!
//! Supported grammar (ample for this repo's configs, loudly errors on the
//! rest): `[section]` headers, `key = value` with string / integer / float
//! / boolean / flat array values, `#` comments, blank lines.  Keys are
//! flattened to `section.key`.

// Toolchain-native twin of lint rule R3: daemon job bodies arrive as
// TOML, so this parser must never panic.  docs/LINT.md.
#![warn(clippy::unwrap_used, clippy::expect_used, clippy::panic)]

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Non-negative integer view (oracle budgets, counters).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse the TOML subset into flattened `section.key → value`.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or(format!("line {}: bad section header", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or(format!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let v = parse_value(value.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        out.insert(full_key, v);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            // i is a char_indices boundary, so get() always succeeds;
            // the fallback just keeps the parser panic-free (R3).
            '#' if !in_str => return line.get(..i).unwrap_or_default(),
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for item in inner.split(',') {
                items.push(parse_value(item.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.replace('_', "").parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used, clippy::panic)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let src = r#"
# experiment
name = "fig2"          # inline comment
[algo]
eta_out = 1.0
rounds = 200
verbose = true
topologies = ["ring", "2hop"]
"#;
        let m = parse(src).unwrap();
        assert_eq!(m["name"].as_str(), Some("fig2"));
        assert_eq!(m["algo.eta_out"].as_f64(), Some(1.0));
        assert_eq!(m["algo.rounds"].as_i64(), Some(200));
        assert_eq!(m["algo.verbose"].as_bool(), Some(true));
        match &m["algo.topologies"] {
            TomlValue::Arr(a) => assert_eq!(a.len(), 2),
            _ => panic!(),
        }
    }

    #[test]
    fn numbers() {
        let m = parse("a = 5\nb = -2.5\nc = 1e-3\nd = 1_000\ne = -3").unwrap();
        assert_eq!(m["a"].as_i64(), Some(5));
        assert_eq!(m["b"].as_f64(), Some(-2.5));
        assert_eq!(m["c"].as_f64(), Some(1e-3));
        assert_eq!(m["d"].as_i64(), Some(1000));
        // u64 view rejects negatives and non-integers.
        assert_eq!(m["a"].as_u64(), Some(5));
        assert_eq!(m["e"].as_u64(), None);
        assert_eq!(m["b"].as_u64(), None);
    }

    #[test]
    fn errors() {
        assert!(parse("[oops").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"unterminated").is_err());
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let m = parse(r##"k = "a#b""##).unwrap();
        assert_eq!(m["k"].as_str(), Some("a#b"));
    }
}
