//! Hot-gossip-path benchmark: the Arc-shared inbox + allocation-free
//! `mix_paid` against the old clone-per-neighbour delivery.
//!
//! ```bash
//! cargo bench --bench gossip
//! ```
//!
//! `naive_*` re-implements the pre-refactor behaviour (every payload
//! cloned once per edge) so the saving is measured, not asserted.

use c2dfb::collective::{Network, Transport};
use c2dfb::compress::{Compressor, TopK};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::rng::Rng;

/// Pre-refactor delivery: one full clone of the payload per edge.
fn naive_exchange_dense(net: &Network, vecs: &[Vec<f32>]) -> Vec<Vec<(usize, Vec<f32>)>> {
    let mut inbox: Vec<Vec<(usize, Vec<f32>)>> = vec![Vec::new(); vecs.len()];
    for (sender, v) in vecs.iter().enumerate() {
        for &nb in net.graph.neighbors(sender) {
            inbox[nb].push((sender, v.clone()));
        }
    }
    inbox
}

/// Pre-refactor mix: materialize the cloned inbox, then fold it.
fn naive_mix_paid(net: &Network, gamma: f64, rows: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let inbox = naive_exchange_dense(net, rows);
    let mut out = rows.to_vec();
    for (i, msgs) in inbox.into_iter().enumerate() {
        for (sender, v) in msgs {
            let w = (gamma * net.mixing.weight(i, sender)) as f32;
            for k in 0..v.len() {
                out[i][k] += w * (v[k] - rows[i][k]);
            }
        }
    }
    out
}

fn main() {
    let mut b = Bencher::from_env();
    let mut rng = Rng::new(1);

    for (m, d, topo, tag) in [
        (10, 20_000, Topology::Ring, "ring_m10_d20k"),
        (16, 4_096, Topology::TwoHopRing, "2hop_m16_d4k"),
        (10, 20_000, Topology::Complete, "complete_m10_d20k"),
    ] {
        let mut net = Network::new(Graph::build(topo, m));
        let rows: Vec<Vec<f32>> = (0..m)
            .map(|_| {
                let mut v = vec![0.0f32; d];
                rng.fill_normal(&mut v, 0.0, 1.0);
                v
            })
            .collect();

        b.bench(&format!("gossip/naive_mix_paid/{tag}"), || {
            black_box(naive_mix_paid(&net, 0.5, &rows))
        });
        b.bench(&format!("gossip/mix_paid/{tag}"), || {
            black_box(net.mix_paid(0.5, &rows))
        });
        b.bench(&format!("gossip/naive_exchange_dense/{tag}"), || {
            black_box(naive_exchange_dense(&net, &rows))
        });
        b.bench(&format!("gossip/exchange_dense_arc/{tag}"), || {
            black_box(net.exchange_dense(&rows))
        });

        // Compressed exchange (inner-loop shape): payload sharing matters
        // less (messages are small) but must not regress.
        let q = TopK::new(0.2);
        let msgs: Vec<_> = rows.iter().map(|v| q.compress(v, &mut rng)).collect();
        b.bench(&format!("gossip/exchange_compressed/{tag}"), || {
            black_box(Transport::exchange(&mut net, msgs.clone()))
        });
    }

    b.finish();
}
