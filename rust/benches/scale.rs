//! Million-node sparse-engine benchmark: rounds/sec and active
//! nodes/sec for `sim::scale::ScaleSim` at m ∈ {1k, 100k, 1M}.
//!
//! ```bash
//! cargo bench --bench scale                 # full ladder (1M included)
//! cargo bench --bench scale -- m100k        # filter one rung
//! SCALE_BENCH_JSON=BENCH_scale.json cargo bench --bench scale
//! ```
//!
//! The headline numbers (recorded in `BENCH_scale.json`, methodology in
//! `docs/SCALE.md`):
//!
//! * **full participation** (`rate = 1.0`) — every node mixes and steps
//!   every round; throughput is bounded by O(m·degree) event traffic;
//! * **sampled** (`rate` chosen so ~1k nodes are active per round) —
//!   the design point: per-round cost tracks the ACTIVE set, so a 1M
//!   node round costs roughly what a 1k-node dense round does plus the
//!   O(m) mask draw.
//!
//! Setting `SCALE_BENCH_JSON=<path>` additionally writes the measured
//! ladder as JSON in the `BENCH_scale.json` shape.

use c2dfb::metrics::ConsensusEstimator;
use c2dfb::sim::{ScaleOpts, ScaleSim};
use c2dfb::topology::Topology;
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::json::Json;

struct Rung {
    tag: &'static str,
    nodes: usize,
    topology: Topology,
    rate: f64,
}

fn main() {
    let mut b = Bencher::from_env();
    // Big single-shot workloads: a short budget is plenty (each iteration
    // is itself thousands-to-millions of node updates).
    b.budget = std::time::Duration::from_secs(1);
    b.min_iters = 3;

    let ladder = [
        Rung { tag: "ring_m1k_full", nodes: 1_000, topology: Topology::Ring, rate: 1.0 },
        Rung { tag: "exp_m1k_full", nodes: 1_000, topology: Topology::Exponential, rate: 1.0 },
        Rung { tag: "ring_m100k_full", nodes: 100_000, topology: Topology::Ring, rate: 1.0 },
        Rung { tag: "ring_m100k_s1pct", nodes: 100_000, topology: Topology::Ring, rate: 0.01 },
        Rung { tag: "ring_m1m_s01pct", nodes: 1_000_000, topology: Topology::Ring, rate: 0.001 },
        Rung { tag: "exp_m1m_s01pct", nodes: 1_000_000, topology: Topology::Exponential, rate: 0.001 },
    ];

    let mut measured: Vec<(String, f64, f64)> = Vec::new(); // (tag, nodes/s, wall_s)
    for rung in &ladder {
        let opts = ScaleOpts {
            nodes: rung.nodes,
            topology: rung.topology,
            rounds: 1,
            rate: rung.rate,
            dim: 8,
            seed: 42,
            eta: 0.1,
            gamma: 0.5,
            estimator: ConsensusEstimator::default(),
        };
        // Bench one round on a persistent engine (steady-state: maps and
        // queue warm); the active node count per round is mask-dependent,
        // so report throughput from an explicit measured pass.
        let mut sim = ScaleSim::new(opts).expect("bench opts are valid");
        let name = format!("scale/round/{}", rung.tag);
        let mean = b.bench(&name, || {
            sim.step_round();
            black_box(sim.tracked_states())
        });
        if let Some(mean) = mean {
            let per_round_active = sim.opts().rate * rung.nodes as f64;
            let nodes_per_sec = per_round_active / mean.as_secs_f64();
            println!("      └─ ~{nodes_per_sec:.3e} active nodes/s");
            measured.push((rung.tag.to_string(), nodes_per_sec, mean.as_secs_f64()));
        }

        // The strided consensus estimate at this m (the eval-point cost).
        let sim2 = ScaleSim::new(opts).expect("bench opts are valid");
        b.bench(&format!("scale/consensus_estimate/{}", rung.tag), || {
            black_box(sim2.consensus_estimate())
        });
    }
    b.finish();

    if let Ok(path) = std::env::var("SCALE_BENCH_JSON") {
        let metrics = Json::obj(
            measured
                .iter()
                .map(|(tag, nps, wall)| {
                    (
                        tag.as_str(),
                        Json::obj(vec![
                            ("active_nodes_per_sec", Json::num(*nps)),
                            ("round_wall_s", Json::num(*wall)),
                        ]),
                    )
                })
                .collect(),
        );
        let doc = Json::obj(vec![
            ("bench", Json::str("scale")),
            ("command", Json::str("cargo bench --bench scale")),
            ("status", Json::str("measured")),
            ("metrics", metrics),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write SCALE_BENCH_JSON");
        println!("wrote {path}");
    }
}
