//! Table 1 bench: end-to-end comm volume & time to target accuracy on the
//! coefficient-tuning task (ring, heterogeneous), C²DFB vs MADSBO vs MDBO.
//!
//! This is the bench-sized version of `c2dfb table1` (fewer rounds so it
//! finishes in bench budgets); the full harness regenerates the paper
//! table — see EXPERIMENTS.md.
//!
//! ```bash
//! cargo bench --bench table1
//! ```

// Bench timing reads the wall clock by design (docs/LINT.md R1).
#![allow(clippy::disallowed_methods)]

use c2dfb::coordinator::experiments::{table1, HarnessOpts};
use c2dfb::runtime::ArtifactRegistry;

fn main() {
    let reg = match ArtifactRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = HarnessOpts {
        rounds: 15,
        out_dir: "runs/bench".into(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let runs = table1(&reg, &opts, 0.7).expect("table1 harness failed");
    println!("\ntable1 bench completed in {:.1}s ({} runs)", t0.elapsed().as_secs_f64(), runs.len());
}
