//! Wall-clock scaling of the sweep orchestrator: one grid executed at
//! pool widths 1, 2 and max, with the per-cell results asserted
//! bit-identical across widths on every measurement (the determinism
//! contract is free to check here, so the bench doubles as a stress
//! test).  Writes the measurements to `BENCH_sweep.json` at the repo
//! root (or `$C2DFB_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench sweep_scaling
//! ```

use c2dfb::coordinator::sweep::{self, SweepSpec};
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::json::Json;

/// A grid heavy enough that cell compute dominates pool overhead: the
/// tiny axes (16 cells) but with real round counts and full-size tasks.
fn spec(jobs: usize) -> SweepSpec {
    let mut s = SweepSpec::tiny();
    s.tiny = false; // full-size task instances
    s.base.nodes = 8;
    s.base.rounds = 10;
    s.base.eval_every = 5;
    s.jobs = jobs;
    s
}

fn main() {
    let mut b = Bencher::quick();
    let max = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // Reference outcomes for the bit-identity assertion.
    let (ref_grid, ref_outcomes) = sweep::run(&spec(1), false).expect("reference sweep");
    let n_cells = ref_grid.cells.len();
    let ref_csv = sweep::report_csv(&ref_grid.cells, &ref_outcomes);

    let mut entries: Vec<(String, Json)> = vec![
        ("cells".into(), Json::num(n_cells as f64)),
        ("rounds".into(), Json::num(10.0)),
        ("max_jobs".into(), Json::num(max as f64)),
    ];

    let mut serial_s = None;
    for jobs in [1usize, 2, max] {
        let sp = spec(jobs);
        let t = b.bench(&format!("sweep/{n_cells}cells/jobs{jobs}"), || {
            let (grid, outcomes) = sweep::run(&sp, false).expect("sweep");
            assert_eq!(
                sweep::diff_outcomes(&ref_outcomes, &outcomes),
                None,
                "jobs={jobs} diverged from the serial reference"
            );
            assert_eq!(ref_csv, sweep::report_csv(&grid.cells, &outcomes));
            black_box(outcomes.len())
        });
        if let Some(t) = t {
            let t = t.as_secs_f64();
            if jobs == 1 {
                serial_s = Some(t);
            }
            if let Some(s) = serial_s {
                println!("      └─ jobs={jobs}: {t:.3}s, speedup {:.2}×", s / t);
            }
            entries.push((format!("wall_s_jobs{jobs}"), Json::num(t)));
            if let Some(s) = serial_s {
                entries.push((format!("speedup_jobs{jobs}"), Json::num(s / t)));
            }
        }
    }

    let pairs: Vec<(&str, Json)> = entries.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    // cargo runs benches with cwd = the package root (rust/); the tracked
    // artifact lives one level up at the repo root.
    let out = std::env::var("C2DFB_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sweep.json").into());
    std::fs::write(&out, Json::obj(pairs).to_string()).expect("write BENCH_sweep.json");
    println!("\nwrote {out}");
    b.finish();
}
