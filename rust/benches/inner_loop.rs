//! Inner-loop (Algorithm 2) benchmark on an analytic quadratic: isolates
//! the L3 coordination cost (mixing + compression + tracking bookkeeping)
//! from oracle latency, reports bytes per inner step per compressor, and
//! — the hot-path contract — **asserts zero heap allocations per
//! steady-state inner step** with a counting global allocator.  The
//! gradient oracle writes into the reusable batch row, so the measured
//! loop is the full `IN` step: mix terms, residuals, compression, the
//! borrowing exchange, and both folds.
//!
//! The assertion runs twice per configuration: once with the default
//! no-op recorder, and once with a pre-sized JSONL trace recorder
//! attached (`obs::Recorder::with_capacity`) — per-step instrumentation
//! only bumps fixed-size aggregates, so tracing must not break the
//! zero-allocation contract either.  Both payload dtypes are covered:
//! the full dim sweep at f32 (the default width), plus an f64 lane at
//! the small dim — the generic kernels must stay allocation-free at
//! either scalar width.
//!
//! Writes `BENCH_inner.json` (override with `$C2DFB_BENCH_INNER_OUT`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use c2dfb::collective::Network;
use c2dfb::compress::parse;
use c2dfb::linalg::{Dtype, Scalar};
use c2dfb::obs::Recorder;
use c2dfb::optim::{run_inner_with, GradFn, InnerConfig, InnerState};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::json::Json;
use c2dfb::util::rng::Rng;

/// Counts every heap allocation (alloc/realloc/alloc_zeroed) so steady-
/// state sections can assert they make none.
struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Heterogeneous quadratic gradients ∇r_i(z) = a_i (z − c_i), written
/// in place — the oracle contributes zero allocations, so the assertion
/// covers the pure coordination cost of a step.  Generated from the f32
/// RNG streams at every dtype (the widening contract, docs/DTYPE.md).
struct Quad<S: Scalar> {
    a: Vec<S>,
    c: Vec<Vec<S>>,
}

impl<S: Scalar> Quad<S> {
    fn build(m: usize, dim: usize, seed: u64) -> Quad<S> {
        let mut rng = Rng::new(seed);
        Quad {
            a: (0..m)
                .map(|_| S::from_f64(rng.uniform_in(0.5, 2.0) as f64))
                .collect(),
            c: (0..m)
                .map(|_| {
                    let mut v = vec![0.0f32; dim];
                    rng.fill_normal(&mut v, 0.0, 1.0);
                    v.into_iter().map(|x| S::from_f64(x as f64)).collect()
                })
                .collect(),
        }
    }

    fn grad_into(&self, i: usize, z: &[S], out: &mut [S]) {
        for ((o, &zk), &ck) in out.iter_mut().zip(z).zip(&self.c[i]) {
            *o = self.a[i] * (zk - ck);
        }
    }
}

/// One (dtype, dim, compressor) configuration: warm up, assert zero
/// steady-state allocations (bare and traced), time a step, and push the
/// result rows.  f32 keeps the historical result keys; f64 rows carry a
/// `+f64` suffix so dashboards track the lanes separately.
fn measure<S: Scalar>(b: &mut Bencher, results: &mut Vec<(String, Json)>, m: usize, dim: usize, spec: &str) {
    let lane = if S::DTYPE == Dtype::F32 { String::new() } else { format!("+{}", S::NAME) };
    let quad: Quad<S> = Quad::build(m, dim, 5);
    let q = parse::<S>(spec).unwrap();
    let mut net = Network::new(Graph::build(Topology::Ring, m));
    let mut rng = Rng::new(2);
    let mut state: InnerState<S> = InnerState::new(&net, dim);
    let mut d = vec![vec![S::ZERO; dim]; m];
    let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
    let mut grad = |i: usize, z: &[S], out: &mut [S]| quad.grad_into(i, z, out);

    // Warm up buffer capacities (bootstrap + first residual rounds),
    // then require exactly zero allocations per step.
    for _ in 0..5 {
        run_inner_with(&cfg, &mut net, q.as_ref(), &mut rng, &mut state, &mut d, GradFn::Serial(&mut grad));
    }
    let steady_steps = 200u64;
    let before_allocs = ALLOCATIONS.load(Ordering::Relaxed);
    let before_bytes = net.ledger.total_bytes;
    for _ in 0..steady_steps {
        run_inner_with(&cfg, &mut net, q.as_ref(), &mut rng, &mut state, &mut d, GradFn::Serial(&mut grad));
    }
    let allocs = ALLOCATIONS.load(Ordering::Relaxed) - before_allocs;
    let kib_per_step = (net.ledger.total_bytes - before_bytes) as f64 / steady_steps as f64 / 1024.0;
    assert_eq!(
        allocs, 0,
        "{spec}{lane} d={dim}: {allocs} heap allocations in {steady_steps} steady-state \
         inner steps — the hot path must not allocate"
    );
    println!("alloc-check inner_step/m10/d{dim}/{spec}{lane}: 0 allocations over {steady_steps} steps");

    // Same contract with the JSONL trace sink attached: per-step
    // instrumentation bumps fixed-size aggregates only (lines are
    // emitted at run/round boundaries, never per step), so a pre-sized
    // recorder must keep the hot path allocation-free.
    state.obs = Recorder::with_capacity(1 << 20, false);
    state.obs.run_start("bench", &format!("d{dim}/{spec}{lane}"), m, 2, spec);
    for _ in 0..5 {
        run_inner_with(&cfg, &mut net, q.as_ref(), &mut rng, &mut state, &mut d, GradFn::Serial(&mut grad));
    }
    let before_traced = ALLOCATIONS.load(Ordering::Relaxed);
    for _ in 0..steady_steps {
        run_inner_with(&cfg, &mut net, q.as_ref(), &mut rng, &mut state, &mut d, GradFn::Serial(&mut grad));
    }
    let traced_allocs = ALLOCATIONS.load(Ordering::Relaxed) - before_traced;
    assert_eq!(
        traced_allocs, 0,
        "{spec}{lane} d={dim}: {traced_allocs} heap allocations in {steady_steps} traced \
         steady-state inner steps — tracing must not allocate on the hot path"
    );
    let trace = state.obs.take_trace().expect("trace sink was attached");
    assert!(
        trace.contains("\"ev\":\"run_start\""),
        "trace recorder attached but recorded nothing"
    );
    state.obs = Recorder::noop();
    println!(
        "alloc-check inner_step/m10/d{dim}/{spec}{lane}+trace: 0 allocations over {steady_steps} steps"
    );

    let name = format!("inner_step/m10/d{dim}/{spec}{lane}");
    let mean = b.bench(&name, || {
        run_inner_with(&cfg, &mut net, q.as_ref(), &mut rng, &mut state, &mut d, GradFn::Serial(&mut grad));
        black_box(d[0][0].to_f64())
    });
    println!("      └─ {kib_per_step:.1} KiB per inner step (all nodes)");
    let key = format!("d{dim}/{spec}{lane}");
    results.push((format!("{key}/allocs_per_step"), Json::num(allocs as f64 / steady_steps as f64)));
    results.push((
        format!("{key}/traced_allocs_per_step"),
        Json::num(traced_allocs as f64 / steady_steps as f64),
    ));
    results.push((format!("{key}/kib_per_step"), Json::num(kib_per_step)));
    results.push((
        format!("{key}/mean_ns"),
        mean.map_or(Json::Null, |t| Json::num(t.as_nanos() as f64)),
    ));
}

fn main() {
    let mut b = Bencher::from_env();
    let m = 10;
    let specs = ["topk:0.2", "randk:0.25", "qsgd:16", "none"];
    let mut results: Vec<(String, Json)> = vec![
        ("bench".into(), Json::str("inner_loop")),
        (
            "description".into(),
            Json::str(
                "Steady-state cost of one compressed inner step (Algorithm 2) on a ring of 10 \
                 nodes, analytic quadratic oracle evaluated in place. allocs_per_step counts \
                 heap allocations via a counting global allocator and MUST be 0 for every \
                 compressor (asserted), both with the no-op recorder and with a pre-sized \
                 JSONL trace recorder attached (traced_allocs_per_step), and at both payload \
                 dtypes (`+f64` rows cover the wide lane).",
            ),
        ),
        ("command".into(), Json::str("cd rust && cargo bench --bench inner_loop")),
    ];

    for dim in [2_000usize, 20_000] {
        for spec in specs {
            measure::<f32>(&mut b, &mut results, m, dim, spec);
        }
    }
    // The wide lane honors the same zero-allocation contract; one dim
    // suffices — the assertion counts allocations, not throughput.
    for spec in specs {
        measure::<f64>(&mut b, &mut results, m, 2_000, spec);
    }
    b.finish();

    // cargo runs benches with cwd = the package root (rust/); the tracked
    // artifact lives one level up at the repo root.
    let out = std::env::var("C2DFB_BENCH_INNER_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_inner.json").into());
    std::fs::write(&out, Json::Obj(results.into_iter().collect()).to_string() + "\n")
        .expect("write BENCH_inner.json");
    println!("wrote {out}");
}
