//! Inner-loop (Algorithm 2) benchmark on the analytic quadratic: isolates
//! the L3 coordination cost (mixing + compression + tracking bookkeeping)
//! from oracle latency, and reports bytes per inner step per compressor —
//! the convergence-theory sanity row of the DESIGN.md experiment index.

use c2dfb::collective::Network;
use c2dfb::compress::parse;
use c2dfb::optim::{run_inner, InnerConfig, InnerState};
use c2dfb::tasks::{BilevelTask, QuadraticTask};
use c2dfb::topology::{Graph, Topology};
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::rng::Rng;

fn main() {
    let mut b = Bencher::from_env();
    let m = 10;
    for dim in [2_000usize, 20_000] {
        let task = QuadraticTask::generate(m, dim, 0.8, 5);
        let x = task.init_x(&mut Rng::new(1));
        let xs: Vec<Vec<f32>> = vec![x; m];
        for spec in ["topk:0.2", "qsgd:16", "none"] {
            let q = parse(spec).unwrap();
            let mut net = Network::new(Graph::build(Topology::Ring, m));
            let mut rng = Rng::new(2);
            let mut state = InnerState::new(&net, dim);
            let mut d = vec![vec![0.0f32; dim]; m];
            let cfg = InnerConfig { eta: 0.1, gamma: 0.5, k_steps: 1 };
            let xs_ref = &xs;
            let before = net.ledger.total_bytes;
            b.bench(&format!("inner_step/m10/d{dim}/{spec}"), || {
                run_inner(
                    &cfg,
                    &mut net,
                    q.as_ref(),
                    &mut rng,
                    &mut state,
                    &mut d,
                    |i, z| task.inner_z_grad(i, &xs_ref[i], z).unwrap(),
                );
                black_box(d[0][0])
            });
            let steps = net.ledger.gossip_rounds / 2; // 2 exchanges per step
            if steps > 0 {
                println!(
                    "      └─ {:.1} KiB per inner step (all nodes)",
                    (net.ledger.total_bytes - before) as f64 / steps as f64 / 1024.0
                );
            }
        }
    }
    b.finish();
}
