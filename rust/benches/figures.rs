//! Figure benches: bench-sized versions of the Fig. 2/4 (coefficient
//! tuning grid), Fig. 3/6 (hyper-representation grid) and Fig. 5
//! (sensitivity) harnesses.  Full-scale regeneration is `c2dfb all`; this
//! binary runs reduced-round versions so `cargo bench` exercises every
//! figure path end to end and prints the same rows.
//!
//! ```bash
//! cargo bench --bench figures [-- fig2|fig3|fig5|ablation]
//! ```

// Bench timing reads the wall clock by design (docs/LINT.md R1).
#![allow(clippy::disallowed_methods)]

use c2dfb::coordinator::experiments::{compressor_ablation, fig2, fig3, fig5, HarnessOpts};
use c2dfb::runtime::ArtifactRegistry;

fn main() {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with("--"));
    let want = |name: &str| filter.as_deref().map(|f| name.contains(f)).unwrap_or(true);

    let reg = match ArtifactRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts`");
            return;
        }
    };
    let opts = HarnessOpts {
        rounds: 6,
        out_dir: "runs/bench".into(),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    if want("fig2") || want("fig4") {
        fig2(&reg, &opts).expect("fig2 harness failed");
    }
    if want("fig3") || want("fig6") {
        fig3(&reg, &opts).expect("fig3 harness failed");
    }
    if want("fig5") {
        fig5(&reg, &opts).expect("fig5 harness failed");
    }
    if want("ablation") {
        compressor_ablation(&reg, &opts).expect("ablation harness failed");
    }
    println!("\nfigures bench completed in {:.1}s", t0.elapsed().as_secs_f64());
}
