//! Micro-benchmarks for the L3 hot path: compressors, gossip mixing,
//! reference-point updates, tracking, and PJRT oracle latency.
//!
//! ```bash
//! cargo bench --bench micro [-- filter]
//! ```

use c2dfb::collective::Network;
use c2dfb::compress::{parse, Compressor};
use c2dfb::config::ExperimentConfig;
use c2dfb::coordinator::build_task;
use c2dfb::optim::RefPoint;
use c2dfb::runtime::ArtifactRegistry;
use c2dfb::tasks::BilevelTask;
use c2dfb::topology::{Graph, MixingMatrix, Topology};
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::rng::Rng;

fn main() {
    let mut b = Bencher::from_env();
    let mut rng = Rng::new(1);

    // --- compressors at the coeff-task message size (dy = 20_000) -------
    let d = 20_000;
    let mut v = vec![0.0f32; d];
    rng.fill_normal(&mut v, 0.0, 1.0);
    for spec in ["topk:0.2", "topk:0.05", "randk:0.2", "qsgd:16", "none"] {
        let q = parse(spec).unwrap();
        b.bench_throughput(&format!("compress/{spec}/d20k"), d as f64, "coord", || {
            black_box(q.compress(&v, &mut rng))
        });
    }
    {
        let q = parse("topk:0.2").unwrap();
        let msg = q.compress(&v, &mut rng);
        let mut out = vec![0.0f32; d];
        b.bench("decompress/topk:0.2/d20k", || {
            msg.decompress_into(&mut out);
            black_box(out[0])
        });
    }

    // --- gossip mixing (dense) at outer-loop size (dx = 2_000, m = 10) --
    let graph = Graph::build(Topology::Ring, 10);
    let w = MixingMatrix::metropolis(&graph);
    let rows: Vec<Vec<f32>> = (0..10)
        .map(|_| {
            let mut r = vec![0.0f32; 2000];
            rng.fill_normal(&mut r, 0.0, 1.0);
            r
        })
        .collect();
    b.bench("mixing/dense/m10/d2k", || black_box(w.mix(0.5, &rows)));

    let mut net = Network::new(Graph::build(Topology::Ring, 10));
    b.bench("network/exchange_dense/m10/d2k", || {
        black_box(net.exchange_dense(&rows))
    });

    // --- reference-point protocol step (d = 20_000) ----------------------
    {
        let q = parse("topk:0.2").unwrap();
        let mut rp = RefPoint::new(d, 0.66);
        let target = v.clone();
        b.bench("refpoint/residual+compress+apply/d20k", || {
            let msg = q.compress(&rp.residual(&target), &mut rng);
            rp.apply_own(&msg);
            black_box(msg.wire_bytes())
        });
    }

    // --- spectral gap computation (setup cost, m = 50) -------------------
    let big = Graph::build(Topology::ErdosRenyi { p_milli: 300, seed: 3 }, 50);
    b.bench("topology/metropolis+eigen/m50", || {
        black_box(MixingMatrix::metropolis(&big).spectral_gap)
    });

    // --- PJRT oracle latency (the per-inner-step cost) -------------------
    if let Ok(reg) = ArtifactRegistry::open_default() {
        for preset in ["coeff", "coeff_jnp"] {
            if !reg.has_preset(preset) {
                continue;
            }
            let task = build_task(
                &reg,
                &ExperimentConfig { preset: preset.into(), nodes: 2, ..Default::default() },
            )
            .unwrap();
            let x = vec![0.0f32; task.dx()];
            let y = vec![0.01f32; task.dy()];
            b.bench(&format!("oracle/{preset}/inner_z_grad"), || {
                black_box(task.inner_z_grad(0, &x, &y).unwrap())
            });
            b.bench(&format!("oracle/{preset}/inner_y_grad"), || {
                black_box(task.inner_y_grad(0, &x, &y, 10.0).unwrap())
            });
            b.bench(&format!("oracle/{preset}/hypergrad"), || {
                black_box(task.hypergrad(0, &x, &y, &y, 10.0).unwrap())
            });
            b.bench(&format!("oracle/{preset}/eval"), || {
                black_box(task.eval(0, &x, &y).unwrap())
            });
        }
    } else {
        eprintln!("artifacts not built; skipping PJRT oracle benches");
    }

    b.finish();
}
