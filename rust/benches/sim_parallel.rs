//! Wall-clock comparison: synchronous serial loop vs the `sim::NodePool`
//! parallel per-node executor, on the analytic quadratic task at 8 and 16
//! nodes.  Writes the measurements to `BENCH_sim.json` at the repo root
//! (or `$C2DFB_BENCH_OUT`).
//!
//! ```bash
//! cargo bench --bench sim_parallel
//! ```

use c2dfb::config::{Algorithm, ExperimentConfig};
use c2dfb::coordinator::Runner;
use c2dfb::tasks::QuadraticTask;
use c2dfb::util::bench::{black_box, Bencher};
use c2dfb::util::json::Json;

fn run_with_task(
    task: &QuadraticTask,
    cfg: &ExperimentConfig,
) -> anyhow::Result<c2dfb::metrics::RunMetrics> {
    Runner::new(cfg).task(task).run()
}

fn run_with_task_shared(
    task: &QuadraticTask,
    cfg: &ExperimentConfig,
) -> anyhow::Result<c2dfb::metrics::RunMetrics> {
    Runner::new(cfg).shared_task(task).run()
}

fn cfg(nodes: usize, threads: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        algorithm: Algorithm::C2dfb,
        nodes,
        rounds: 6,
        inner_steps: 10,
        eta_out: 0.3,
        eta_in: 0.4,
        gamma_out: 0.8,
        gamma_in: 0.6,
        lambda: 50.0,
        compressor: "topk:0.2".into(),
        eval_every: 6,
        ..ExperimentConfig::default()
    };
    cfg.network.threads = threads;
    cfg
}

fn main() {
    let mut b = Bencher::quick();
    // Dimension large enough that oracle math (O(m·d) per batch) dominates
    // the pool's fan-out overhead.
    let dim = 65_536;
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    let mut entries: Vec<(String, Json)> = vec![
        ("task".into(), Json::str("quadratic")),
        ("dim".into(), Json::num(dim as f64)),
        ("rounds".into(), Json::num(6.0)),
        ("inner_steps".into(), Json::num(10.0)),
        ("threads".into(), Json::num(threads as f64)),
    ];

    for nodes in [8usize, 16] {
        let task: QuadraticTask = QuadraticTask::generate(nodes, dim, 0.8, 7);

        let serial = b.bench(&format!("sim/serial/m{nodes}"), || {
            black_box(run_with_task(&task, &cfg(nodes, 1)).unwrap())
        });
        let parallel = b.bench(&format!("sim/parallel/m{nodes}/t{threads}"), || {
            black_box(run_with_task_shared(&task, &cfg(nodes, threads)).unwrap())
        });

        if let (Some(s), Some(p)) = (serial, parallel) {
            let (s, p) = (s.as_secs_f64(), p.as_secs_f64());
            println!("      └─ m={nodes}: serial {s:.3}s, parallel {p:.3}s, speedup {:.2}×", s / p);
            entries.push((format!("serial_s_m{nodes}"), Json::num(s)));
            entries.push((format!("parallel_s_m{nodes}"), Json::num(p)));
            entries.push((format!("speedup_m{nodes}"), Json::num(s / p)));
        }
    }

    let pairs: Vec<(&str, Json)> = entries.iter().map(|(k, v)| (k.as_str(), v.clone())).collect();
    // cargo runs benches with cwd = the package root (rust/); the tracked
    // artifact lives one level up at the repo root.
    let out = std::env::var("C2DFB_BENCH_OUT")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_sim.json").into());
    std::fs::write(&out, Json::obj(pairs).to_string()).expect("write BENCH_sim.json");
    println!("\nwrote {out}");
    b.finish();
}
