#!/usr/bin/env python3
"""Fill EXPERIMENTS.md placeholder tables from runs/final/ summaries."""

import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
FINAL = ROOT / "runs" / "final"


def load(run_dir: str):
    out = []
    d = FINAL / run_dir
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def mb_to_acc(csv_path: pathlib.Path, target: float):
    for line in csv_path.read_text().splitlines()[1:]:
        parts = line.split(",")
        if float(parts[5]) >= target:
            return float(parts[1])
    return None


def fig_table(run_dir: str, target_acc=None, loss_target=None):
    rows = ["| run | algo | final acc | final loss | comm (MB) | MB to target |",
            "|---|---|---|---|---|---|"]
    for s in load(run_dir):
        label = s["label"].replace(f"{run_dir}_", "")
        csv = FINAL / run_dir / (s["algo"] + "_" + s["label"].replace(" ", "_").replace("/", "_") + ".csv")
        tgt = ""
        if csv.exists():
            if target_acc is not None:
                v = mb_to_acc(csv, target_acc)
                tgt = f"{v:.1f}" if v is not None else "—"
            elif loss_target is not None:
                for line in csv.read_text().splitlines()[1:]:
                    parts = line.split(",")
                    try:
                        if float(parts[4]) <= loss_target:
                            tgt = f"{float(parts[1]):.1f}"
                            break
                    except ValueError:
                        continue
                tgt = tgt or "—"
        fl = s["final_loss"]
        fl = f"{fl:.4f}" if fl is not None else "NaN"
        rows.append(
            f"| {label} | {s['algo']} | {s['final_accuracy']:.3f} | {fl} "
            f"| {s['comm_mb']:.0f} | {tgt} |"
        )
    return "\n".join(rows)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    md = md.replace("<!-- FIG2_TABLE -->", fig_table("fig2", target_acc=0.7))
    md = md.replace("<!-- FIG3_TABLE -->", fig_table("fig3", loss_target=0.5))
    md = md.replace("<!-- FIG5_TABLE -->", fig_table("fig5"))
    md = md.replace("<!-- ABLATION_TABLE -->", fig_table("ablation_compressor"))
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md tables filled")


if __name__ == "__main__":
    main()
