"""AOT pipeline: lower every registered entry point to HLO text + manifest.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``../artifacts``):

    <preset>/<entry>.hlo.txt      one module per oracle
    manifest.json                 shapes/dtypes per entry + preset dims

Run via ``make artifacts``; the Rust runtime consumes the manifest.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from .model import presets


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_dict(s) -> dict:
    return {"shape": list(s.shape), "dtype": s.dtype.name}


def lower_entry(fn, example_args):
    # keep_unused: some oracles legitimately ignore an input (e.g. the CE
    # Hessian does not depend on the labels); without this, XLA prunes the
    # parameter and the Rust runtime's positional marshalling breaks.
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    out_specs = [
        _spec_dict(o) for o in jax.eval_shape(fn, *example_args)
    ]
    in_specs = [_spec_dict(s) for s in example_args]
    return text, in_specs, out_specs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).resolve().parents[2] / "artifacts"))
    ap.add_argument("--presets", default="all", help="comma list or 'all' / 'tiny'")
    args = ap.parse_args()

    reg = presets()
    if args.presets == "all":
        selected = list(reg)
    elif args.presets == "tiny":
        selected = [n for n in reg if n.endswith("_tiny") or n == "demo"]
    else:
        selected = args.presets.split(",")
        unknown = [n for n in selected if n not in reg]
        if unknown:
            sys.exit(f"unknown presets: {unknown}; available: {sorted(reg)}")

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest_path = out_dir / "manifest.json"
    manifest = {"version": 1, "entries": {}, "presets": {}}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())

    t0 = time.time()
    for pname in selected:
        preset = reg[pname]
        pdir = out_dir / pname
        pdir.mkdir(exist_ok=True)
        entries = preset.build()
        manifest["presets"][pname] = {
            "task": preset.task,
            "kernels": preset.kernels,
            "dims": preset.dims.to_dict() if preset.dims is not None else {},
        }
        for ename, (fn, ex) in entries.items():
            key = f"{pname}.{ename}"
            text, in_specs, out_specs = lower_entry(fn, ex)
            rel = f"{pname}/{ename}.hlo.txt"
            (out_dir / rel).write_text(text)
            manifest["entries"][key] = {
                "file": rel,
                "inputs": in_specs,
                "outputs": out_specs,
                "kernels": preset.kernels,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            }
            print(f"  lowered {key:28s} {len(text)/1024:8.1f} KiB", flush=True)
    manifest_path.write_text(json.dumps(manifest, indent=1, sort_keys=True))
    print(f"wrote {manifest_path} ({len(manifest['entries'])} entries) in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
