"""L2 oracle bundle for the Hyper-Representation task (paper §6.2).

Three-layer MLP on MNIST-shaped data; the *outer* variable x is the flattened
backbone (input→h1→h2, ReLU), the *inner* variable y is the flattened linear
classification head (h2→classes):

    f_i(x, y) = CE(head(backbone(A_val; x); y), B_val)           (upper)
    g_i(x, y) = CE(head(backbone(A_tr;  x); y), B_tr) + (μ/2)‖y‖² (lower)

The small ridge term (HEAD_REG) makes g strongly convex in y, matching
Assumption 2.  With the paper's sizes (784→100→64→10) the backbone has
84,964 parameters and the head 650 — the dx ≫ dy asymmetry that drives the
compression story.

All entry points are flat-f32 in/out; λ is a runtime scalar input.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .ops import Ops, accuracy, cross_entropy

HEAD_REG = 5e-4


@dataclass(frozen=True)
class HyperRepDims:
    inputs: int
    hidden1: int
    hidden2: int
    classes: int
    n_train: int
    n_val: int

    @property
    def dx(self) -> int:
        return (
            self.inputs * self.hidden1
            + self.hidden1
            + self.hidden1 * self.hidden2
            + self.hidden2
        )

    @property
    def dy(self) -> int:
        return self.hidden2 * self.classes + self.classes

    def to_dict(self) -> dict:
        return {
            "inputs": self.inputs,
            "hidden1": self.hidden1,
            "hidden2": self.hidden2,
            "classes": self.classes,
            "n_train": self.n_train,
            "n_val": self.n_val,
            "dx": self.dx,
            "dy": self.dy,
        }


FULL = HyperRepDims(inputs=784, hidden1=100, hidden2=64, classes=10, n_train=256, n_val=128)
TINY = HyperRepDims(inputs=16, hidden1=8, hidden2=8, classes=4, n_train=32, n_val=16)


def build(dims: HyperRepDims, k: Ops) -> dict:
    I, H1, H2, C = dims.inputs, dims.hidden1, dims.hidden2, dims.classes

    def unpack_x(xf):
        o = 0
        w1 = xf[o : o + I * H1].reshape(I, H1); o += I * H1
        b1 = xf[o : o + H1]; o += H1
        w2 = xf[o : o + H1 * H2].reshape(H1, H2); o += H1 * H2
        b2 = xf[o : o + H2]; o += H2
        return w1, b1, w2, b2

    def unpack_y(yf):
        w3 = yf[: H2 * C].reshape(H2, C)
        b3 = yf[H2 * C :]
        return w3, b3

    def logits(xf, yf, a):
        w1, b1, w2, b2 = unpack_x(xf)
        w3, b3 = unpack_y(yf)
        h1 = k.dense_relu(a, w1, b1)
        h2 = k.dense_relu(h1, w2, b2)
        return k.dense(h2, w3, b3)

    def g_loss(xf, yf, atr, btr):
        return cross_entropy(logits(xf, yf, atr), btr) + 0.5 * HEAD_REG * jnp.vdot(yf, yf)

    def f_loss(xf, yf, aval, bval):
        return cross_entropy(logits(xf, yf, aval), bval)

    def h_loss(xf, yf, lam, atr, btr, aval, bval):
        return f_loss(xf, yf, aval, bval) + lam * g_loss(xf, yf, atr, btr)

    # --- C²DFB first-order oracles -------------------------------------
    def inner_y(xf, yf, lam, atr, btr, aval, bval):
        return (jax.grad(h_loss, argnums=1)(xf, yf, lam, atr, btr, aval, bval),)

    def inner_z(xf, zf, atr, btr):
        return (jax.grad(g_loss, argnums=1)(xf, zf, atr, btr),)

    def hyper(xf, yf, zf, lam, atr, btr, aval, bval):
        """u = ∇_x f(x,y) + λ(∇_x g(x,y) − ∇_x g(x,z)), assembled via the
        fused penalty kernel from three backbone backward passes."""
        gxf = jax.grad(f_loss, argnums=0)(xf, yf, aval, bval)
        gxy = jax.grad(g_loss, argnums=0)(xf, yf, atr, btr)
        gxz = jax.grad(g_loss, argnums=0)(xf, zf, atr, btr)
        return (k.penalty_combine(gxf, gxy, gxz, lam),)

    def evaluate(xf, yf, aval, bval):
        lg = logits(xf, yf, aval)
        return cross_entropy(lg, bval), accuracy(lg, bval)

    # --- Second-order oracles (baselines only) --------------------------
    # g is CE in the *head* only, so with features H2 = backbone(x; A) the
    # y-Hessian has the closed CE form (custom_vjp kernels are not
    # twice-differentiable, so we write it out).  The cross term ∇²_xy g · v
    # is a single reverse pass over x of ⟨∇_y g (closed form), v⟩.
    def _softmax(lg):
        z = lg - jnp.max(lg, axis=1, keepdims=True)
        e = jnp.exp(z)
        return e / jnp.sum(e, axis=1, keepdims=True)

    def backbone(xf, a):
        w1, b1, w2, b2 = unpack_x(xf)
        return k.dense_relu(k.dense_relu(a, w1, b1), w2, b2)

    def grad_y_g_closed(xf, yf, atr, btr):
        h2 = backbone(xf, atr)
        w3, b3 = unpack_y(yf)
        p = _softmax(k.dense(h2, w3, b3))
        r = (p - btr) / dims.n_train
        gw = k.matmul(h2.T, r)
        gb = jnp.sum(r, axis=0)
        return jnp.concatenate([gw.reshape(-1), gb]) + HEAD_REG * yf

    def hvp_yy_g(xf, yf, v, atr, btr):
        h2 = backbone(xf, atr)
        w3, b3 = unpack_y(yf)
        vw, vb = unpack_y(v)
        p = _softmax(k.dense(h2, w3, b3))
        q = k.matmul(h2, vw) + vb[None, :]
        w = p * q - p * jnp.sum(p * q, axis=1, keepdims=True)
        hw = k.matmul(h2.T, w) / dims.n_train
        hb = jnp.sum(w, axis=0) / dims.n_train
        return (jnp.concatenate([hw.reshape(-1), hb]) + HEAD_REG * v,)

    def jvp_xy_g(xf, yf, v, atr, btr):
        phi = lambda xx: jnp.vdot(grad_y_g_closed(xx, yf, atr, btr), v)
        return (jax.grad(phi)(xf),)

    def grad_y_f(xf, yf, aval, bval):
        return (jax.grad(f_loss, argnums=1)(xf, yf, aval, bval),)

    def grad_x_f(xf, yf, aval, bval):
        return (jax.grad(f_loss, argnums=0)(xf, yf, aval, bval),)

    f32 = jnp.float32
    x_s = jax.ShapeDtypeStruct((dims.dx,), f32)
    y_s = jax.ShapeDtypeStruct((dims.dy,), f32)
    lam_s = jax.ShapeDtypeStruct((), f32)
    atr_s = jax.ShapeDtypeStruct((dims.n_train, I), f32)
    btr_s = jax.ShapeDtypeStruct((dims.n_train, C), f32)
    aval_s = jax.ShapeDtypeStruct((dims.n_val, I), f32)
    bval_s = jax.ShapeDtypeStruct((dims.n_val, C), f32)

    return {
        "inner_y": (inner_y, (x_s, y_s, lam_s, atr_s, btr_s, aval_s, bval_s)),
        "inner_z": (inner_z, (x_s, y_s, atr_s, btr_s)),
        "hyper": (hyper, (x_s, y_s, y_s, lam_s, atr_s, btr_s, aval_s, bval_s)),
        "eval": (evaluate, (x_s, y_s, aval_s, bval_s)),
        "hvp_yy_g": (hvp_yy_g, (x_s, y_s, y_s, atr_s, btr_s)),
        "jvp_xy_g": (jvp_xy_g, (x_s, y_s, y_s, atr_s, btr_s)),
        "grad_y_f": (grad_y_f, (x_s, y_s, aval_s, bval_s)),
        "grad_x_f": (grad_x_f, (x_s, y_s, aval_s, bval_s)),
    }
