"""Pallas kernels (L1) and their pure-jnp oracles.

Public surface:

* :func:`matmul.matmul` — tiled, differentiable matmul.
* :func:`mlp.dense_relu` / :func:`mlp.dense` — fused MLP layers.
* :func:`elementwise.penalty_combine` — hypergradient assembly.
* :func:`elementwise.exp_reg_grad` — coefficient-tuning regularizer grad.
* :mod:`ref` — jnp oracles, one per kernel.
"""

from . import elementwise, matmul, mlp, ref, tiling  # noqa: F401
