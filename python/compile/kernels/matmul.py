"""L1 Pallas kernel: tiled matmul with a custom VJP.

This is the compute hot-spot of both reproduction tasks — every oracle
(logits, backward contractions ``AᵀR``, MLP layers) routes through this
kernel, so when the L2 graphs are lowered the whole model compute sits in
Pallas-generated HLO.

TPU mental model (see DESIGN.md §Hardware-Adaptation): the grid walks
``(M/bm, N/bn, K/bk)`` output/reduction tiles; each step keeps a
``(bm, bn)`` f32 output tile resident in VMEM while streaming
``(bm, bk)`` / ``(bk, bn)`` operand tiles HBM→VMEM via BlockSpec, i.e. the
classic MXU systolic-array schedule.  Lowered with ``interpret=True`` so the
CPU PJRT client can execute it (real-TPU lowering emits a Mosaic
custom-call; see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps: int):
    """One grid step: accumulate ``x_tile @ y_tile`` into the output tile.

    The output BlockSpec maps every K-step of a given ``(i, j)`` tile onto
    the same VMEM block, so ``o_ref`` doubles as the f32 accumulator — no
    separate scratch needed and no HBM round-trip between K steps.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_padded(a, b, bm: int, bn: int, bk: int):
    """Pallas matmul over block-multiple operands."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    grid = (tiling.cdiv(m, bm), tiling.cdiv(n, bn), tiling.cdiv(k, bk))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _matmul_impl(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pad → pallas matmul → slice."""
    m, k = a.shape
    _, n = b.shape
    bm = tiling.pick_block(m, tiling.BLOCK_M)
    bn = tiling.pick_block(n, tiling.BLOCK_N)
    bk = tiling.pick_block(k, tiling.BLOCK_K)
    mp, kp, np_ = tiling.ceil_to(m, bm), tiling.ceil_to(k, bk), tiling.ceil_to(n, bn)
    ap = tiling.pad2(a, mp, kp)
    bp = tiling.pad2(b, kp, np_)
    out = _matmul_padded(ap, bp, bm, bn, bk)
    return out[:m, :n].astype(a.dtype)


@jax.custom_vjp
def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """``a @ b`` through the Pallas tiled kernel, differentiable.

    The VJP routes both cotangent contractions (``g @ bᵀ`` and ``aᵀ @ g``)
    through the same kernel, so backward passes are Pallas compute too.
    """
    return _matmul_impl(a, b)


def _matmul_fwd(a, b):
    return _matmul_impl(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    # Route through the custom_vjp wrapper (not _matmul_impl) so that
    # higher-order differentiation — e.g. the reverse-over-reverse HVP
    # oracles used by the second-order baselines — stays in reverse mode
    # instead of hitting pallas_call's missing JVP rule.
    return matmul(g, b.T), matmul(a.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
