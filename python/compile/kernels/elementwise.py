"""L1 Pallas kernels: fused elementwise stages of the C²DFB oracles.

Two fusions that sit on the per-round hot path of every node:

* ``penalty_combine`` — the fully first-order hypergradient assembly
  ``u = gxf + λ·(gxg_y − gxg_z)`` (paper Eq. 4 / Alg. 1 "Local Gradients
  Computation").  One pass over the upper-level dimension.
* ``exp_reg_grad`` — the coefficient-tuning regularizer gradients: given the
  per-feature log-weights ``x`` and the squashed squared rows ``r = Σ_c y²``
  it returns ``exp(x) ⊙ r`` (this is ∂/∂x of ``Σ_fc exp(x_f) y_fc²``).

Both are 1-D grids over VMEM-resident vector tiles; under ``interpret=True``
they lower to plain HLO loops the CPU PJRT client can run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling

_BLOCK = 8192


def _penalty_kernel(gxf_ref, gy_ref, gz_ref, lam_ref, o_ref):
    lam = lam_ref[0]
    o_ref[...] = gxf_ref[...] + lam * (gy_ref[...] - gz_ref[...])


def _penalty_impl(gxf, gy, gz, lam):
    (d,) = gxf.shape
    blk = tiling.pick_block(d, _BLOCK)
    dp = tiling.ceil_to(d, blk)
    args = [tiling.pad1(v, dp) for v in (gxf, gy, gz)]
    lam_v = jnp.reshape(lam.astype(jnp.float32), (1,))
    out = pl.pallas_call(
        _penalty_kernel,
        grid=(tiling.cdiv(dp, blk),),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            # The scalar multiplier rides along in every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(*args, lam_v)
    return out[:d]


def penalty_combine(gxf, gy, gz, lam):
    """``gxf + lam * (gy - gz)`` fused in one Pallas pass."""
    return _penalty_impl(gxf, gy, gz, lam)


def _exp_reg_kernel(x_ref, r_ref, o_ref):
    o_ref[...] = jnp.exp(x_ref[...]) * r_ref[...]


@jax.custom_vjp
def exp_reg_grad(x, r):
    """``exp(x) * r`` fused in one Pallas pass (differentiable)."""
    return _exp_reg_impl(x, r)


def _exp_reg_impl(x, r):
    (d,) = x.shape
    blk = tiling.pick_block(d, _BLOCK)
    dp = tiling.ceil_to(d, blk)
    out = pl.pallas_call(
        _exp_reg_kernel,
        grid=(tiling.cdiv(dp, blk),),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=True,
    )(tiling.pad1(x, dp), tiling.pad1(r, dp))
    return out[:d]


def _exp_reg_fwd(x, r):
    y = _exp_reg_impl(x, r)
    return y, (x, r, y)


def _exp_reg_bwd(res, g):
    x, r, y = res
    # d/dx (exp(x) r) = exp(x) r = y ; d/dr = exp(x)
    return g * y, g * jnp.exp(x)


exp_reg_grad.defvjp(_exp_reg_fwd, _exp_reg_bwd)


def _relu_kernel(x_ref, o_ref, m_ref):
    v = x_ref[...]
    o_ref[...] = jnp.maximum(v, 0.0)
    m_ref[...] = (v > 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=())
def relu_with_mask(x2d):
    """Fused ReLU that also emits the backward mask, tiled over rows."""
    m, n = x2d.shape
    bm = tiling.pick_block(m, 128)
    bn = tiling.pick_block(n, 128)
    mp, np_ = tiling.ceil_to(m, bm), tiling.ceil_to(n, bn)
    out, mask = pl.pallas_call(
        _relu_kernel,
        grid=(tiling.cdiv(mp, bm), tiling.cdiv(np_, bn)),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(tiling.pad2(x2d, mp, np_))
    return out[:m, :n], mask[:m, :n]
