"""L1 Pallas building block: fused dense+ReLU layer with a custom VJP.

``dense_relu(x, w, b)`` computes ``relu(x @ w + b)`` with the matmul on the
Pallas tiled kernel and the bias+activation fused in a Pallas elementwise
pass that also emits the ReLU mask consumed by the backward pass.  The VJP
contracts cotangents through the same tiled matmul kernel, so the entire MLP
fwd+bwd is Pallas compute.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import tiling
from .matmul import _matmul_impl


def _bias_relu_kernel(z_ref, b_ref, o_ref, m_ref):
    v = z_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(v, 0.0)
    m_ref[...] = (v > 0.0).astype(jnp.float32)


def _bias_relu(z, b):
    m, n = z.shape
    bm = tiling.pick_block(m, 128)
    bn = tiling.pick_block(n, 128)
    mp, np_ = tiling.ceil_to(m, bm), tiling.ceil_to(n, bn)
    out, mask = pl.pallas_call(
        _bias_relu_kernel,
        grid=(tiling.cdiv(mp, bm), tiling.cdiv(np_, bn)),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
            jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        ],
        interpret=True,
    )(tiling.pad2(z, mp, np_), tiling.pad2(b[None, :], 1, np_))
    return out[:m, :n], mask[:m, :n]


@jax.custom_vjp
def dense_relu(x, w, b):
    """Fused ``relu(x @ w + b)`` on Pallas kernels, differentiable."""
    z = _matmul_impl(x, w)
    out, _ = _bias_relu(z, b)
    return out


def _dense_relu_fwd(x, w, b):
    z = _matmul_impl(x, w)
    out, mask = _bias_relu(z, b)
    return out, (x, w, mask)


def _dense_relu_bwd(res, g):
    x, w, mask = res
    # `matmul` (custom_vjp), not `_matmul_impl`: keeps second-order
    # differentiation (HVP oracles) in reverse mode through this bwd.
    from .matmul import matmul

    gz = g * mask
    gx = matmul(gz, w.T)
    gw = matmul(x.T, gz)
    gb = jnp.sum(gz, axis=0)
    return gx, gw, gb


dense_relu.defvjp(_dense_relu_fwd, _dense_relu_bwd)


def dense(x, w, b):
    """Plain affine layer ``x @ w + b`` on the Pallas matmul (differentiable
    through matmul's own VJP; bias add is trivially fused by XLA)."""
    from .matmul import matmul

    return matmul(x, w) + b[None, :]
