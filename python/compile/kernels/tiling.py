"""Tiling helpers shared by the Pallas kernels.

All kernels in this package operate on block-padded operands: the public
wrappers pad every dimension up to a multiple of the block size, launch the
kernel on the padded grid, and slice the result back.  This keeps the kernel
bodies branch-free (no partial-tile masking) which is both simpler and closer
to how an MXU-targeted kernel would be written (8x128-aligned tiles).
"""

from __future__ import annotations

import jax.numpy as jnp

# Default block sizes.  On a real TPU these map onto MXU-friendly
# (8k x 128)-aligned tiles; under interpret=True they only control the grid
# of the emitted HLO loop.  Perf note (EXPERIMENTS.md §Perf): the interpret
# path executes one XLA while-loop iteration per grid step, so small blocks
# multiply loop/dynamic-slice overhead into the CPU hot path — 512-blocks
# cut the coeff-task oracle latency ~8x vs 128-blocks while staying inside
# a plausible TPU VMEM budget (512x512 f32 = 1 MiB/tile, 3 tiles resident
# < 16 MiB VMEM).
BLOCK_M = 512
BLOCK_N = 512
BLOCK_K = 512


def ceil_to(x: int, b: int) -> int:
    """Round ``x`` up to the next multiple of ``b``."""
    return ((x + b - 1) // b) * b


def cdiv(x: int, b: int) -> int:
    """Ceiling division."""
    return (x + b - 1) // b


def pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    """Zero-pad a 2-D array up to ``(rows, cols)``."""
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def pad1(a: jnp.ndarray, n: int) -> jnp.ndarray:
    """Zero-pad a 1-D array up to length ``n``."""
    (m,) = a.shape
    if m == n:
        return a
    return jnp.pad(a, (0, n - m))


def pick_block(dim: int, preferred: int, floor: int = 8) -> int:
    """Choose a block size for a dimension.

    Small problem dims (the tiny test preset) should not be padded all the
    way to 128; pick the smallest power-of-two >= dim instead, bounded below
    by ``floor`` so the VMEM tile stays vector-register aligned.
    """
    if dim >= preferred:
        return preferred
    b = floor
    while b < dim:
        b *= 2
    return b
