"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest (plus hypothesis sweeps over
shapes/dtypes) asserts ``assert_allclose(kernel(...), ref(...))`` for each
kernel, and the L2 task modules can be built against either implementation
(``use_pallas=False`` routes through these), which is how the ``*_jnp``
artifact variants for the L2 perf ablation are produced.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def penalty_combine(gxf, gy, gz, lam):
    return gxf + lam * (gy - gz)


def exp_reg_grad(x, r):
    return jnp.exp(x) * r


def relu_with_mask(x2d):
    return jnp.maximum(x2d, 0.0), (x2d > 0.0).astype(jnp.float32)


def dense_relu(x, w, b):
    return jnp.maximum(x @ w + b[None, :], 0.0)


def dense(x, w, b):
    return x @ w + b[None, :]
