"""L2 oracle bundle for the Coefficient-Tuning task (paper §6.1).

Bilevel problem per node i (20-Newsgroups-style linear classifier with a
per-feature exponential regularizer tuned at the upper level):

    f_i(x, y) = CE(A_val · Y, B_val)                       (upper / validation)
    g_i(x, y) = CE(A_tr  · Y, B_tr) + Σ_fc exp(x_f) Y_fc²  (lower / training)

with x ∈ R^F (log regularization weights) and Y ∈ R^{F×C} flattened to
y ∈ R^{F·C}.  Note ∇_x f ≡ 0 for this task; the hypergradient reduces to
``u = λ (∇_x g(x,y) − ∇_x g(x,z))`` with ∇_x g(x,·) = exp(x) ⊙ Σ_c (·)².

Every entry point takes and returns flat f32 arrays so the Rust runtime can
marshal buffers straight from its parameter vectors.  λ is a runtime scalar
input (not baked into the HLO) so the Fig. 5 sensitivity sweep does not
re-AOT.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .ops import Ops, accuracy, cross_entropy


@dataclass(frozen=True)
class CoeffDims:
    features: int
    classes: int
    n_train: int
    n_val: int

    @property
    def dx(self) -> int:
        return self.features

    @property
    def dy(self) -> int:
        return self.features * self.classes

    def to_dict(self) -> dict:
        return {
            "features": self.features,
            "classes": self.classes,
            "n_train": self.n_train,
            "n_val": self.n_val,
            "dx": self.dx,
            "dy": self.dy,
        }


FULL = CoeffDims(features=2000, classes=10, n_train=256, n_val=128)
TINY = CoeffDims(features=64, classes=4, n_train=32, n_val=16)


def build(dims: CoeffDims, k: Ops) -> dict:
    """Return ``{entry_name: (fn, example_args)}`` for AOT lowering."""
    F, C = dims.features, dims.classes

    def unpack(yf):
        return yf.reshape(F, C)

    def g_loss(x, yf, atr, btr):
        y = unpack(yf)
        logits = k.matmul(atr, y)
        r = jnp.sum(y * y, axis=1)  # Σ_c y_fc² per feature
        reg = jnp.sum(k.exp_reg_grad(x, r))
        return cross_entropy(logits, btr) + reg

    def f_loss(yf, aval, bval):
        logits = k.matmul(aval, unpack(yf))
        return cross_entropy(logits, bval)

    def h_loss(x, yf, lam, atr, btr, aval, bval):
        return f_loss(yf, aval, bval) + lam * g_loss(x, yf, atr, btr)

    # --- C²DFB first-order oracles -------------------------------------
    def inner_y(x, yf, lam, atr, btr, aval, bval):
        """∇_y h(x, y) — inner-loop oracle for the y sequence."""
        return (jax.grad(h_loss, argnums=1)(x, yf, lam, atr, btr, aval, bval),)

    def inner_z(x, zf, atr, btr):
        """∇_y g(x, z) — inner-loop oracle for the z sequence."""
        return (jax.grad(g_loss, argnums=1)(x, zf, atr, btr),)

    def hyper(x, yf, zf, lam):
        """Fully first-order hypergradient u (paper Eq. 4).

        ∇_x g(x, ·) has the closed form exp(x) ⊙ Σ_c (·)², assembled with
        the fused Pallas kernels; ∇_x f ≡ 0 for this task.
        """
        ry = jnp.sum(unpack(yf) ** 2, axis=1)
        rz = jnp.sum(unpack(zf) ** 2, axis=1)
        gy = k.exp_reg_grad(x, ry)
        gz = k.exp_reg_grad(x, rz)
        return (k.penalty_combine(jnp.zeros_like(x), gy, gz, lam),)

    def evaluate(yf, aval, bval):
        """(validation CE loss, accuracy) for the upper-level metric."""
        logits = k.matmul(aval, unpack(yf))
        return cross_entropy(logits, bval), accuracy(logits, bval)

    # --- Second-order oracles (baselines MADSBO / MDBO only) -----------
    # Closed forms: the CE Hessian-vector product is
    #   (∇²_yy CE)·V = Aᵀ[p⊙(AV) − p⊙rowsum(p⊙(AV))]/N
    # and the regularizer contributes 2 exp(x) ⊙ V; the cross term is
    #   (∇²_xy g)·V = 2 exp(x) ⊙ Σ_c y ⊙ V.
    # (custom_vjp kernels are not twice-differentiable, so these are
    # written out rather than derived by reverse-over-reverse.)
    def _softmax(logits):
        z = logits - jnp.max(logits, axis=1, keepdims=True)
        e = jnp.exp(z)
        return e / jnp.sum(e, axis=1, keepdims=True)

    def hvp_yy_g(x, yf, v, atr, btr):
        y, vv = unpack(yf), unpack(v)
        p = _softmax(k.matmul(atr, y))
        q = k.matmul(atr, vv)
        w = p * q - p * jnp.sum(p * q, axis=1, keepdims=True)
        h = k.matmul(atr.T, w) / dims.n_train + 2.0 * jnp.exp(x)[:, None] * vv
        return (h.reshape(-1),)

    def jvp_xy_g(x, yf, v):
        y, vv = unpack(yf), unpack(v)
        return (2.0 * jnp.exp(x) * jnp.sum(y * vv, axis=1),)

    def grad_y_f(yf, aval, bval):
        return (jax.grad(f_loss, argnums=0)(yf, aval, bval),)

    def grad_x_f(x, yf):
        return (jnp.zeros_like(x),)

    f32 = jnp.float32
    x_s = jax.ShapeDtypeStruct((F,), f32)
    y_s = jax.ShapeDtypeStruct((F * C,), f32)
    lam_s = jax.ShapeDtypeStruct((), f32)
    atr_s = jax.ShapeDtypeStruct((dims.n_train, F), f32)
    btr_s = jax.ShapeDtypeStruct((dims.n_train, C), f32)
    aval_s = jax.ShapeDtypeStruct((dims.n_val, F), f32)
    bval_s = jax.ShapeDtypeStruct((dims.n_val, C), f32)

    return {
        "inner_y": (inner_y, (x_s, y_s, lam_s, atr_s, btr_s, aval_s, bval_s)),
        "inner_z": (inner_z, (x_s, y_s, atr_s, btr_s)),
        "hyper": (hyper, (x_s, y_s, y_s, lam_s)),
        "eval": (evaluate, (y_s, aval_s, bval_s)),
        "hvp_yy_g": (hvp_yy_g, (x_s, y_s, y_s, atr_s, btr_s)),
        "jvp_xy_g": (jvp_xy_g, (x_s, y_s, y_s)),
        "grad_y_f": (grad_y_f, (y_s, aval_s, bval_s)),
        "grad_x_f": (grad_x_f, (x_s, y_s)),
    }
