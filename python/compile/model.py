"""L2 registry: every AOT entry point this repo lowers, by preset.

A *preset* is (task, dims, kernel backend).  The Rust runtime selects a
preset by name and reads per-entry shapes from the manifest that
:mod:`compile.aot` writes alongside the HLO text files.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from . import task_coeff, task_hyperrep
from .ops import get_ops


@dataclass(frozen=True)
class Preset:
    name: str
    task: str
    kernels: str  # "pallas" | "jnp"
    dims: object
    build: Callable[[], dict]


def _demo_affine():
    """Tiny smoke artifact used by the Rust runtime unit tests."""

    def affine(a, b):
        return (jnp.matmul(a, b) + 1.0,)

    s = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    return {"affine": (affine, (s, s))}


def presets() -> dict:
    out = {}

    def add(name, task, dims, use_pallas):
        mod = {"coeff": task_coeff, "hyperrep": task_hyperrep}[task]
        k = get_ops(use_pallas)
        out[name] = Preset(
            name=name,
            task=task,
            kernels=k.name,
            dims=dims,
            build=lambda mod=mod, dims=dims, k=k: mod.build(dims, k),
        )

    add("coeff", "coeff", task_coeff.FULL, use_pallas=True)
    add("coeff_tiny", "coeff", task_coeff.TINY, use_pallas=True)
    add("coeff_jnp", "coeff", task_coeff.FULL, use_pallas=False)
    add("hyperrep", "hyperrep", task_hyperrep.FULL, use_pallas=True)
    add("hyperrep_tiny", "hyperrep", task_hyperrep.TINY, use_pallas=True)
    add("hyperrep_jnp", "hyperrep", task_hyperrep.FULL, use_pallas=False)

    out["demo"] = Preset(
        name="demo", task="demo", kernels="jnp", dims=None, build=_demo_affine
    )
    return out
