"""Kernel-backend selector for the L2 graphs.

Every task module takes an ``Ops`` namespace so the same model definitions
can be lowered either through the Pallas kernels (default artifacts) or the
pure-jnp reference implementations (the ``*_jnp`` artifact variants used by
the L2 perf ablation and as a cross-check of the whole lowered pipeline).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax.numpy as jnp

from .kernels import elementwise, matmul, mlp, ref


@dataclass(frozen=True)
class Ops:
    name: str
    matmul: Callable
    dense_relu: Callable
    dense: Callable
    penalty_combine: Callable
    exp_reg_grad: Callable


PALLAS = Ops(
    name="pallas",
    matmul=matmul.matmul,
    dense_relu=mlp.dense_relu,
    dense=mlp.dense,
    penalty_combine=elementwise.penalty_combine,
    exp_reg_grad=elementwise.exp_reg_grad,
)

JNP = Ops(
    name="jnp",
    matmul=ref.matmul,
    dense_relu=ref.dense_relu,
    dense=ref.dense,
    penalty_combine=ref.penalty_combine,
    exp_reg_grad=ref.exp_reg_grad,
)


def get_ops(use_pallas: bool) -> Ops:
    return PALLAS if use_pallas else JNP


def cross_entropy(logits: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy against one-hot targets."""
    logz = logits - jnp.max(logits, axis=1, keepdims=True)
    logz = logz - jnp.log(jnp.sum(jnp.exp(logz), axis=1, keepdims=True))
    return -jnp.mean(jnp.sum(onehot * logz, axis=1))


def accuracy(logits: jnp.ndarray, onehot: jnp.ndarray) -> jnp.ndarray:
    pred = jnp.argmax(logits, axis=1)
    truth = jnp.argmax(onehot, axis=1)
    return jnp.mean((pred == truth).astype(jnp.float32))
