"""Fused elementwise Pallas kernels vs jnp oracles."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise, ref

DIM = st.integers(min_value=1, max_value=5000)


def _rand(n, seed, scale=1.0):
    return jnp.asarray(np.random.RandomState(seed).randn(n) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(d=DIM, lam=st.floats(0.0, 100.0), seed=st.integers(0, 2**16))
def test_penalty_combine(d, lam, seed):
    gxf, gy, gz = _rand(d, seed), _rand(d, seed + 1), _rand(d, seed + 2)
    got = elementwise.penalty_combine(gxf, gy, gz, jnp.float32(lam))
    want = ref.penalty_combine(gxf, gy, gz, lam)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(d=DIM, seed=st.integers(0, 2**16))
def test_exp_reg_grad(d, seed):
    x = _rand(d, seed, scale=0.5)
    r = jnp.abs(_rand(d, seed + 1))
    np.testing.assert_allclose(
        elementwise.exp_reg_grad(x, r), ref.exp_reg_grad(x, r), rtol=1e-5, atol=1e-6
    )


def test_exp_reg_grad_vjp():
    x = _rand(300, 0, scale=0.3)
    r = jnp.abs(_rand(300, 1))
    f_k = lambda x, r: jnp.sum(elementwise.exp_reg_grad(x, r) ** 2)
    f_r = lambda x, r: jnp.sum(ref.exp_reg_grad(x, r) ** 2)
    gk = jax.grad(f_k, (0, 1))(x, r)
    gr = jax.grad(f_r, (0, 1))(x, r)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 100), n=st.integers(1, 100), seed=st.integers(0, 2**16))
def test_relu_with_mask(m, n, seed):
    x = jnp.asarray(np.random.RandomState(seed).randn(m, n), jnp.float32)
    got_v, got_m = elementwise.relu_with_mask(x)
    want_v, want_m = ref.relu_with_mask(x)
    np.testing.assert_allclose(got_v, want_v)
    np.testing.assert_allclose(got_m, want_m)


def test_penalty_combine_zero_lambda_is_identity_on_gxf():
    gxf, gy, gz = _rand(77, 3), _rand(77, 4), _rand(77, 5)
    got = elementwise.penalty_combine(gxf, gy, gz, jnp.float32(0.0))
    np.testing.assert_allclose(got, gxf, rtol=1e-6)
