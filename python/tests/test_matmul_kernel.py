"""Pallas tiled matmul vs the pure-jnp oracle — the core L1 signal.

Hypothesis sweeps shapes (including partial-tile and >1-tile cases) and
dtypes; explicit cases pin the grid-edge geometries.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

DIM = st.integers(min_value=1, max_value=200)


def _rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**16))
def test_matmul_matches_ref_shapes(m, k, n, seed):
    a = _rand((m, k), seed)
    b = _rand((k, n), seed + 1)
    np.testing.assert_allclose(
        matmul.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),
        (8, 8, 8),
        (128, 128, 128),  # exactly one tile
        (129, 127, 130),  # one past / one short of a tile boundary
        (256, 384, 256),  # multi-tile in every dim
        (3, 500, 2),      # deep-K reduction walk
    ],
)
def test_matmul_grid_edges(m, k, n):
    a = _rand((m, k), 0)
    b = _rand((k, n), 1)
    np.testing.assert_allclose(
        matmul.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    a = _rand((33, 65), 2, dtype)
    b = _rand((65, 17), 3, dtype)
    got = matmul.matmul(a, b)
    want = ref.matmul(a, b)
    assert got.dtype == want.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64))
def test_matmul_vjp_matches_ref(m, k, n):
    a = _rand((m, k), 7)
    b = _rand((k, n), 8)
    g = _rand((m, n), 9)

    def loss_k(a, b):
        return jnp.vdot(matmul.matmul(a, b), g)

    def loss_r(a, b):
        return jnp.vdot(ref.matmul(a, b), g)

    ga_k, gb_k = jax.grad(loss_k, (0, 1))(a, b)
    ga_r, gb_r = jax.grad(loss_r, (0, 1))(a, b)
    np.testing.assert_allclose(ga_k, ga_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_k, gb_r, rtol=1e-4, atol=1e-4)


def test_matmul_under_jit_and_vmap_free_compose():
    # jit(grad(jit(...))) — the composition the AOT pipeline exercises.
    a = _rand((20, 30), 4)
    b = _rand((30, 10), 5)
    f = jax.jit(jax.grad(lambda a: jnp.sum(matmul.matmul(a, b) ** 2)))
    fr = jax.jit(jax.grad(lambda a: jnp.sum(ref.matmul(a, b) ** 2)))
    np.testing.assert_allclose(f(a), fr(a), rtol=1e-4, atol=1e-4)
