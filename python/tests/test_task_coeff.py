"""Coefficient-tuning oracles (Pallas build) vs independent jnp autodiff.

The reference side here is written from the math, NOT from compile.ops —
plain jnp losses differentiated by jax.grad / reverse-over-reverse — so it
independently checks both the closed-form second-order oracles and the
custom-VJP plumbing of the Pallas build.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import task_coeff
from compile.ops import get_ops

DIMS = task_coeff.TINY
F, C, NTR, NVAL = DIMS.features, DIMS.classes, DIMS.n_train, DIMS.n_val


@pytest.fixture(scope="module")
def entries():
    return task_coeff.build(DIMS, get_ops(use_pallas=True))


@pytest.fixture(scope="module")
def data():
    rs = np.random.RandomState(42)
    x = jnp.asarray(rs.randn(F) * 0.1, jnp.float32)
    y = jnp.asarray(rs.randn(F * C) * 0.1, jnp.float32)
    z = jnp.asarray(rs.randn(F * C) * 0.1, jnp.float32)
    v = jnp.asarray(rs.randn(F * C), jnp.float32)
    atr = jnp.asarray(rs.randn(NTR, F), jnp.float32)
    btr = jnp.asarray(np.eye(C, dtype=np.float32)[rs.randint(0, C, NTR)])
    aval = jnp.asarray(rs.randn(NVAL, F), jnp.float32)
    bval = jnp.asarray(np.eye(C, dtype=np.float32)[rs.randint(0, C, NVAL)])
    return x, y, z, v, atr, btr, aval, bval


def _ce(logits, onehot):
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(onehot * logz, axis=1))


def g_jnp(x, yf, atr, btr):
    y = yf.reshape(F, C)
    return _ce(atr @ y, btr) + jnp.sum(jnp.exp(x)[:, None] * y * y)


def f_jnp(yf, aval, bval):
    return _ce(aval @ yf.reshape(F, C), bval)


LAM = jnp.float32(7.5)


def test_inner_y_is_grad_of_h(entries, data):
    x, y, _, _, atr, btr, aval, bval = data
    (got,) = entries["inner_y"][0](x, y, LAM, atr, btr, aval, bval)
    want = jax.grad(
        lambda yy: f_jnp(yy, aval, bval) + LAM * g_jnp(x, yy, atr, btr)
    )(y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_inner_z_is_grad_of_g(entries, data):
    x, _, z, _, atr, btr, _, _ = data
    (got,) = entries["inner_z"][0](x, z, atr, btr)
    want = jax.grad(lambda zz: g_jnp(x, zz, atr, btr))(z)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_hyper_matches_autodiff_penalty_gradient(entries, data):
    x, y, z, _, atr, btr, _, _ = data
    (got,) = entries["hyper"][0](x, y, z, LAM)
    gxy = jax.grad(lambda xx: g_jnp(xx, y, atr, btr))(x)
    gxz = jax.grad(lambda xx: g_jnp(xx, z, atr, btr))(x)
    want = LAM * (gxy - gxz)  # ∇x f ≡ 0 for this task
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_eval_loss_and_accuracy(entries, data):
    _, y, _, _, _, _, aval, bval = data
    loss, acc = entries["eval"][0](y, aval, bval)
    np.testing.assert_allclose(loss, f_jnp(y, aval, bval), rtol=1e-5)
    pred = jnp.argmax(aval @ y.reshape(F, C), axis=1)
    want_acc = jnp.mean((pred == jnp.argmax(bval, axis=1)).astype(jnp.float32))
    np.testing.assert_allclose(acc, want_acc)


def test_hvp_yy_matches_reverse_over_reverse(entries, data):
    x, y, _, v, atr, btr, _, _ = data
    (got,) = entries["hvp_yy_g"][0](x, y, v, atr, btr)
    want = jax.grad(
        lambda yy: jnp.vdot(jax.grad(lambda w: g_jnp(x, w, atr, btr))(yy), v)
    )(y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_jvp_xy_matches_reverse_over_reverse(entries, data):
    x, y, _, v, atr, btr, _, _ = data
    (got,) = entries["jvp_xy_g"][0](x, y, v)
    want = jax.grad(
        lambda xx: jnp.vdot(jax.grad(lambda w: g_jnp(xx, w, atr, btr))(y), v)
    )(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_grad_y_f(entries, data):
    _, y, _, _, _, _, aval, bval = data
    (got,) = entries["grad_y_f"][0](y, aval, bval)
    want = jax.grad(lambda yy: f_jnp(yy, aval, bval))(y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_grad_x_f_is_zero(entries, data):
    x, y = data[0], data[1]
    (got,) = entries["grad_x_f"][0](x, y)
    np.testing.assert_allclose(got, jnp.zeros_like(x))


def test_hvp_is_symmetric_psd_direction(entries, data):
    """g is strongly convex in y ⇒ vᵀ(∇²_yy g)v ≥ 2·min(exp(x))·‖v‖²."""
    x, y, _, v, atr, btr, _, _ = data
    (hv,) = entries["hvp_yy_g"][0](x, y, v, atr, btr)
    quad = float(jnp.vdot(v, hv))
    mu = 2.0 * float(jnp.min(jnp.exp(x)))
    assert quad >= mu * float(jnp.vdot(v, v)) * 0.999


def test_pallas_and_jnp_variants_agree(data):
    x, y, z, v, atr, btr, aval, bval = data
    ep = task_coeff.build(DIMS, get_ops(True))
    ej = task_coeff.build(DIMS, get_ops(False))
    for name, args in [
        ("inner_y", (x, y, LAM, atr, btr, aval, bval)),
        ("inner_z", (x, z, atr, btr)),
        ("hyper", (x, y, z, LAM)),
        ("hvp_yy_g", (x, y, v, atr, btr)),
    ]:
        got = ep[name][0](*args)
        want = ej[name][0](*args)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4, err_msg=name)
