"""Fused dense+ReLU Pallas layer vs jnp oracle, fwd and bwd."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref


def _rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_dense_relu_fwd(m, k, n, seed):
    x, w, b = _rand((m, k), seed), _rand((k, n), seed + 1), _rand((n,), seed + 2)
    np.testing.assert_allclose(
        mlp.dense_relu(x, w, b), ref.dense_relu(x, w, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 48), k=st.integers(2, 48), n=st.integers(2, 48))
def test_dense_relu_bwd(m, k, n):
    x, w, b = _rand((m, k), 0), _rand((k, n), 1), _rand((n,), 2)
    g = _rand((m, n), 3)
    f_k = lambda x, w, b: jnp.vdot(mlp.dense_relu(x, w, b), g)
    f_r = lambda x, w, b: jnp.vdot(ref.dense_relu(x, w, b), g)
    gk = jax.grad(f_k, (0, 1, 2))(x, w, b)
    gr = jax.grad(f_r, (0, 1, 2))(x, w, b)
    for a, bb in zip(gk, gr):
        np.testing.assert_allclose(a, bb, rtol=1e-4, atol=1e-4)


def test_dense_matches_ref():
    x, w, b = _rand((17, 33), 4), _rand((33, 9), 5), _rand((9,), 6)
    np.testing.assert_allclose(
        mlp.dense(x, w, b), ref.dense(x, w, b), rtol=1e-4, atol=1e-4
    )


def test_two_layer_stack_grad():
    """Composition used by the hyper-representation backbone."""
    x = _rand((12, 20), 7)
    w1, b1 = _rand((20, 16), 8), _rand((16,), 9)
    w2, b2 = _rand((16, 8), 10), _rand((8,), 11)

    def net(k, w1, b1, w2, b2):
        return jnp.sum(k.dense_relu(k.dense_relu(x, w1, b1), w2, b2) ** 2)

    gk = jax.grad(lambda *a: net(mlp, *a), (0, 1, 2, 3))(w1, b1, w2, b2)
    gr = jax.grad(lambda *a: net(ref, *a), (0, 1, 2, 3))(w1, b1, w2, b2)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
