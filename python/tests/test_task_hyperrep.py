"""Hyper-representation oracles (Pallas build) vs independent jnp autodiff."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import task_hyperrep
from compile.ops import get_ops

DIMS = task_hyperrep.TINY
I, H1, H2, C = DIMS.inputs, DIMS.hidden1, DIMS.hidden2, DIMS.classes
NTR, NVAL = DIMS.n_train, DIMS.n_val
REG = task_hyperrep.HEAD_REG


@pytest.fixture(scope="module")
def entries():
    return task_hyperrep.build(DIMS, get_ops(use_pallas=True))


@pytest.fixture(scope="module")
def data():
    rs = np.random.RandomState(7)
    x = jnp.asarray(rs.randn(DIMS.dx) * 0.2, jnp.float32)
    y = jnp.asarray(rs.randn(DIMS.dy) * 0.2, jnp.float32)
    z = jnp.asarray(rs.randn(DIMS.dy) * 0.2, jnp.float32)
    v = jnp.asarray(rs.randn(DIMS.dy), jnp.float32)
    atr = jnp.asarray(rs.randn(NTR, I), jnp.float32)
    btr = jnp.asarray(np.eye(C, dtype=np.float32)[rs.randint(0, C, NTR)])
    aval = jnp.asarray(rs.randn(NVAL, I), jnp.float32)
    bval = jnp.asarray(np.eye(C, dtype=np.float32)[rs.randint(0, C, NVAL)])
    return x, y, z, v, atr, btr, aval, bval


def _unpack_x(xf):
    o = 0
    w1 = xf[o : o + I * H1].reshape(I, H1); o += I * H1
    b1 = xf[o : o + H1]; o += H1
    w2 = xf[o : o + H1 * H2].reshape(H1, H2); o += H1 * H2
    b2 = xf[o : o + H2]; o += H2
    return w1, b1, w2, b2


def _logits(xf, yf, a):
    w1, b1, w2, b2 = _unpack_x(xf)
    w3 = yf[: H2 * C].reshape(H2, C)
    b3 = yf[H2 * C :]
    h1 = jnp.maximum(a @ w1 + b1[None, :], 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2[None, :], 0.0)
    return h2 @ w3 + b3[None, :]


def _ce(logits, onehot):
    return -jnp.mean(jnp.sum(onehot * jax.nn.log_softmax(logits), axis=1))


def g_jnp(xf, yf, atr, btr):
    return _ce(_logits(xf, yf, atr), btr) + 0.5 * REG * jnp.vdot(yf, yf)


def f_jnp(xf, yf, aval, bval):
    return _ce(_logits(xf, yf, aval), bval)


LAM = jnp.float32(4.0)


def test_inner_y_is_grad_of_h(entries, data):
    x, y, _, _, atr, btr, aval, bval = data
    (got,) = entries["inner_y"][0](x, y, LAM, atr, btr, aval, bval)
    want = jax.grad(
        lambda yy: f_jnp(x, yy, aval, bval) + LAM * g_jnp(x, yy, atr, btr),
    )(y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_inner_z_is_grad_of_g(entries, data):
    x, _, z, _, atr, btr, _, _ = data
    (got,) = entries["inner_z"][0](x, z, atr, btr)
    want = jax.grad(lambda zz: g_jnp(x, zz, atr, btr))(z)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_hyper_matches_autodiff_penalty_gradient(entries, data):
    x, y, z, _, atr, btr, aval, bval = data
    (got,) = entries["hyper"][0](x, y, z, LAM, atr, btr, aval, bval)
    gxf = jax.grad(lambda xx: f_jnp(xx, y, aval, bval))(x)
    gxy = jax.grad(lambda xx: g_jnp(xx, y, atr, btr))(x)
    gxz = jax.grad(lambda xx: g_jnp(xx, z, atr, btr))(x)
    want = gxf + LAM * (gxy - gxz)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_eval(entries, data):
    x, y, _, _, _, _, aval, bval = data
    loss, acc = entries["eval"][0](x, y, aval, bval)
    np.testing.assert_allclose(loss, f_jnp(x, y, aval, bval), rtol=1e-4)
    pred = jnp.argmax(_logits(x, y, aval), axis=1)
    want_acc = jnp.mean((pred == jnp.argmax(bval, axis=1)).astype(jnp.float32))
    np.testing.assert_allclose(acc, want_acc)


def test_hvp_yy_matches_reverse_over_reverse(entries, data):
    x, y, _, v, atr, btr, _, _ = data
    (got,) = entries["hvp_yy_g"][0](x, y, v, atr, btr)
    want = jax.grad(
        lambda yy: jnp.vdot(jax.grad(lambda w: g_jnp(x, w, atr, btr))(yy), v)
    )(y)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_jvp_xy_matches_reverse_over_reverse(entries, data):
    x, y, _, v, atr, btr, _, _ = data
    (got,) = entries["jvp_xy_g"][0](x, y, v, atr, btr)
    want = jax.grad(
        lambda xx: jnp.vdot(jax.grad(lambda w: g_jnp(xx, w, atr, btr))(y), v)
    )(x)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=2e-4)


def test_grad_y_f_and_grad_x_f(entries, data):
    x, y, _, _, _, _, aval, bval = data
    (gy,) = entries["grad_y_f"][0](x, y, aval, bval)
    (gx,) = entries["grad_x_f"][0](x, y, aval, bval)
    np.testing.assert_allclose(
        gy, jax.grad(lambda yy: f_jnp(x, yy, aval, bval))(y), rtol=1e-3, atol=1e-4
    )
    np.testing.assert_allclose(
        gx, jax.grad(lambda xx: f_jnp(xx, y, aval, bval))(x), rtol=1e-3, atol=1e-4
    )


def test_head_hessian_strong_convexity(entries, data):
    """vᵀ(∇²_yy g)v ≥ REG·‖v‖² — Assumption 2 holds by construction."""
    x, y, _, v, atr, btr, _, _ = data
    (hv,) = entries["hvp_yy_g"][0](x, y, v, atr, btr)
    assert float(jnp.vdot(v, hv)) >= 0.999 * REG * float(jnp.vdot(v, v))


def test_dims_match_paper_scale():
    """Full preset ≈ paper's 81,902 backbone / 640 head split."""
    full = task_hyperrep.FULL
    assert 80_000 <= full.dx <= 90_000
    assert 600 <= full.dy <= 700


def test_pallas_and_jnp_variants_agree(data):
    x, y, z, v, atr, btr, aval, bval = data
    ep = task_hyperrep.build(DIMS, get_ops(True))
    ej = task_hyperrep.build(DIMS, get_ops(False))
    for name, args in [
        ("inner_y", (x, y, LAM, atr, btr, aval, bval)),
        ("inner_z", (x, z, atr, btr)),
        ("hyper", (x, y, z, LAM, atr, btr, aval, bval)),
        ("grad_x_f", (x, y, aval, bval)),
    ]:
        got = ep[name][0](*args)
        want = ej[name][0](*args)
        for a, b in zip(got, want):
            np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4, err_msg=name)
