"""AOT pipeline: HLO text well-formedness + manifest/shape consistency."""

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import pytest

from compile import aot, model

REPO = Path(__file__).resolve().parents[2]
ART = REPO / "artifacts"


def test_presets_registry_complete():
    reg = model.presets()
    for name in ["coeff", "coeff_tiny", "coeff_jnp", "hyperrep", "hyperrep_tiny", "hyperrep_jnp", "demo"]:
        assert name in reg
    for pname, preset in reg.items():
        entries = preset.build()
        assert entries, pname
        if preset.task != "demo":
            for e in ["inner_y", "inner_z", "hyper", "eval", "hvp_yy_g", "jvp_xy_g", "grad_y_f", "grad_x_f"]:
                assert e in entries, f"{pname} missing {e}"


def test_lower_entry_emits_parseable_hlo_text():
    reg = model.presets()
    fn, ex = reg["demo"].build()["affine"]
    text, in_specs, out_specs = aot.lower_entry(fn, ex)
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    assert len(in_specs) == 2 and in_specs[0]["shape"] == [8, 8]
    assert out_specs[0]["shape"] == [8, 8]


def test_manifest_shapes_match_eval_shape():
    """For the tiny presets, the on-disk manifest must agree with what the
    registry would lower today (guards against stale artifacts)."""
    if not (ART / "manifest.json").exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    manifest = json.loads((ART / "manifest.json").read_text())
    reg = model.presets()
    for pname in ["coeff_tiny", "hyperrep_tiny"]:
        if pname not in manifest["presets"]:
            pytest.skip(f"{pname} not in manifest")
        entries = reg[pname].build()
        for ename, (fn, ex) in entries.items():
            key = f"{pname}.{ename}"
            ment = manifest["entries"][key]
            assert (ART / ment["file"]).exists(), key
            got_in = [list(s.shape) for s in ex]
            assert [e["shape"] for e in ment["inputs"]] == got_in, key
            outs = jax.eval_shape(fn, *ex)
            assert [e["shape"] for e in ment["outputs"]] == [list(o.shape) for o in outs], key


def test_manifest_records_kernel_backend():
    if not (ART / "manifest.json").exists():
        pytest.skip("artifacts not built")
    manifest = json.loads((ART / "manifest.json").read_text())
    for key, ent in manifest["entries"].items():
        assert ent["kernels"] in ("pallas", "jnp"), key
    presets = manifest["presets"]
    if "coeff" in presets and "coeff_jnp" in presets:
        assert presets["coeff"]["kernels"] == "pallas"
        assert presets["coeff_jnp"]["kernels"] == "jnp"


def test_hlo_files_reference_no_custom_calls():
    """interpret=True must lower to plain HLO — a Mosaic custom-call would
    be unloadable by the CPU PJRT client."""
    if not (ART / "manifest.json").exists():
        pytest.skip("artifacts not built")
    manifest = json.loads((ART / "manifest.json").read_text())
    for key, ent in manifest["entries"].items():
        text = (ART / ent["file"]).read_text()
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower(), key
